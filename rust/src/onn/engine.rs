//! Layer-graph execution: digital fp32 or photonic-simulated CirPTC.

use std::path::Path;

use crate::bail;
use crate::circulant::Bcm;
use crate::data::Bundle;
use crate::simulator::{ChipSim, EncodeSnapshot, EncodedOperand};
use crate::tensor::{self, Tensor};
use crate::util::error::{Context, Result};
use crate::util::scratch;
use crate::util::threadpool::ThreadPool;

use super::manifest::{LayerKind, LayerSpec, Manifest};
use super::plan::{next_tile_owner, LayerPlan, LinearPlan};

/// Execution backend for conv/FC layers.
#[derive(Debug)]
pub enum Backend {
    /// fp32 math — circ layers run the compressed BCM kernels directly
    /// (direct or planned Eq. (2) by the crossover), gemm layers a dense
    /// matmul; no l× dense expansion is ever materialized
    Digital,
    /// every linear layer streamed through the CirPTC simulator as
    /// sign-split positive-only BCM tiles (paper lookup-mode inference)
    PhotonicSim(ChipSim),
}

/// Weights of one linear layer.
struct LinearWeights {
    /// compressed BCM (circ arch) — padded dims (P·l ≥ cout, Q·l ≥ n)
    bcm: Option<Bcm>,
    /// dense (m, n) weight — gemm arch only; circ layers serve every
    /// backend from the compressed form (no l× dense expansion in memory)
    dense: Option<Tensor>,
    bias: Vec<f32>,
}

struct BnWeights {
    gamma: Vec<f32>,
    beta: Vec<f32>,
    mean: Vec<f32>,
    var: Vec<f32>,
}

enum LayerState {
    Linear(LinearWeights),
    Bn(BnWeights),
    Stateless,
}

/// A loaded StrC-ONN ready to execute.
pub struct Engine {
    pub manifest: Manifest,
    /// worker threads for the large batched matmuls (digital path);
    /// results are bit-identical for any value, see [`Tensor::matmul_par`]
    pub threads: usize,
    /// serve through the planned path (cached sign splits, FFT plans,
    /// weight spectra, pre-encoded chip tiles, scratch arenas).  `false`
    /// re-routes every linear layer through the unplanned reference calls
    /// — bit-identical by contract (`rust/tests/planned_path.rs`), kept
    /// as the oracle and the perf baseline.
    pub use_plans: bool,
    layers: Vec<LayerState>,
    /// per-layer planned state, aligned with `layers`
    plans: Vec<LayerPlan>,
    /// this engine's key space in the sims' pre-encoded tile caches; a
    /// hot-swapped replacement engine gets a fresh owner, invalidating
    /// every tile the old engine encoded
    tile_owner: u64,
}

impl Engine {
    /// Load manifest + weight bundle (as exported by `compile.train`).
    pub fn load(manifest_path: &Path, bundle_path: &Path) -> Result<Engine> {
        let manifest = Manifest::load(manifest_path)?;
        let bundle = Bundle::load(bundle_path)?;
        Engine::from_parts(manifest, &bundle)
    }

    /// [`Engine::from_parts_unchecked`] behind the static validator
    /// ([`crate::verify::validate_artifacts`]): structurally broken
    /// artifacts are refused with attributed diagnostics *before* any
    /// layer state is built.
    pub fn from_parts(manifest: Manifest, bundle: &Bundle) -> Result<Engine> {
        crate::verify::validate_artifacts(&manifest, bundle, None)
            .into_result("refusing to build engine from invalid artifacts")?;
        Engine::from_parts_unchecked(manifest, bundle)
    }

    /// Build without the validation pass — for callers that have already
    /// validated (or deliberately want load-time failures instead, e.g.
    /// micro-benches constructing throwaway engines in a hot loop).
    pub fn from_parts_unchecked(manifest: Manifest, bundle: &Bundle) -> Result<Engine> {
        let mut layers = Vec::with_capacity(manifest.layers.len());
        for (i, spec) in manifest.layers.iter().enumerate() {
            let name = format!("layer{i}");
            let state = match spec.kind {
                LayerKind::Conv | LayerKind::Fc => {
                    let n_in = spec.n_in();
                    let w = bundle.get(&format!("{name}.w"))?;
                    let bias =
                        bundle.get(&format!("{name}.b"))?.as_f32()?.to_vec();
                    if spec.arch == "circ" {
                        let (p, q) = spec.bcm_dims();
                        let data = w.as_f32()?;
                        if w.shape() != [p, q, spec.l] {
                            bail!(
                                "{name}.w shape {:?}, expected [{p},{q},{}]",
                                w.shape(), spec.l
                            );
                        }
                        let bcm =
                            Bcm::new(p, q, spec.l, data.to_vec());
                        LayerState::Linear(LinearWeights {
                            bcm: Some(bcm),
                            dense: None,
                            bias,
                        })
                    } else {
                        let data = w.as_f32()?.to_vec();
                        LayerState::Linear(LinearWeights {
                            bcm: None,
                            dense: Some(Tensor::new(&[spec.cout, n_in], data)),
                            bias,
                        })
                    }
                }
                LayerKind::Bn => LayerState::Bn(BnWeights {
                    gamma: bundle.get(&format!("{name}.gamma"))?.as_f32()?.to_vec(),
                    beta: bundle.get(&format!("{name}.beta"))?.as_f32()?.to_vec(),
                    mean: bundle
                        .get(&format!("{name}.state.mean"))?
                        .as_f32()?
                        .to_vec(),
                    var: bundle
                        .get(&format!("{name}.state.var"))?
                        .as_f32()?
                        .to_vec(),
                }),
                _ => LayerState::Stateless,
            };
            layers.push(state);
        }
        // planned execution state: everything invariant between weight
        // changes is built once here, not per batch (DESIGN.md §perf)
        let plans = manifest
            .layers
            .iter()
            .zip(&layers)
            .map(|(spec, state)| match state {
                LayerState::Linear(lw) => match &lw.bcm {
                    Some(bcm) => {
                        LayerPlan::Linear(LinearPlan::new(bcm, spec.n_in()))
                    }
                    None => LayerPlan::Other,
                },
                _ => LayerPlan::Other,
            })
            .collect();
        Ok(Engine {
            manifest,
            threads: ThreadPool::default_size(),
            use_plans: true,
            layers,
            plans,
            tile_owner: next_tile_owner(),
        })
    }

    /// Forward one image (c, h, w) → logits (a batch of one through the
    /// batch-major path).
    pub fn forward(&self, img: &Tensor, backend: &mut Backend) -> Result<Vec<f32>> {
        let mut out =
            self.forward_batch(std::slice::from_ref(img), backend)?;
        out.pop().context("empty forward output")
    }

    /// Forward a batch; returns per-image logits in input order.
    ///
    /// Batch-major end to end: the layer graph is walked **once**, every
    /// activation carries the whole batch — images as `(b, c, h, w)`,
    /// flattened features as `(b, n)` — and each linear layer issues a
    /// single multi-column BCM multiply (one sign-split pass pair on the
    /// photonic backend, however many images are in flight).  Columns are
    /// independent operands throughout, so on deterministic backends the
    /// result is element-wise identical to running [`Engine::forward`]
    /// per image.  (A *noisy* `ChipSim` consumes its RNG stream
    /// layer-by-layer across the whole batch, so individual noise draws
    /// land on different elements than in a per-image loop — same
    /// statistics, different samples.)
    pub fn forward_batch(
        &self,
        imgs: &[Tensor],
        backend: &mut Backend,
    ) -> Result<Vec<Vec<f32>>> {
        // the sequential path IS the staged path run back to back — the
        // stage split can't drift from it because there is nothing else
        // to drift from (rust/tests/pipelined_path.rs pins the overlap)
        let span = crate::obs::trace::begin();
        let photonic = matches!(backend, Backend::PhotonicSim(_));
        let pre = self.pre_batch(imgs, photonic, None)?;
        let mid = self.chip_batch(pre, backend)?;
        let out = self.post_batch(mid);
        crate::obs::trace::end(
            span,
            "forward_batch",
            "engine",
            crate::obs::trace::arg1("size", imgs.len() as i64),
        );
        out
    }

    /// Index of the first conv/fc layer, if any.
    pub(crate) fn first_linear(&self) -> Option<usize> {
        self.manifest
            .layers
            .iter()
            .position(|s| matches!(s.kind, LayerKind::Conv | LayerKind::Fc))
    }

    /// Index of the last conv/fc layer, if any.
    pub(crate) fn last_linear(&self) -> Option<usize> {
        self.manifest
            .layers
            .iter()
            .rposition(|s| matches!(s.kind, LayerKind::Conv | LayerKind::Fc))
    }

    /// **Pre stage** (electronic, chip-free): validate + pack the image
    /// batch, run every layer before the first linear, and pack the first
    /// linear's operand (im2col / transpose + activation-scale clamp +
    /// row padding).  With an [`EncodeSnapshot`] the operand is also
    /// quantized + Γ-mixed here — the expensive half of a chip pass —
    /// stamped with the snapshot generation so the chip stage can reject
    /// it if the chip moved in between.  Touches neither the backend nor
    /// any engine state, so a pipeline may run it for batch *i+1* while
    /// batch *i* is still on the chip.
    pub fn pre_batch(
        &self,
        imgs: &[Tensor],
        photonic: bool,
        snap: Option<&EncodeSnapshot>,
    ) -> Result<PreBatch> {
        if imgs.is_empty() {
            return Ok(PreBatch { state: PreState::Empty });
        }
        let shape = &imgs[0].shape;
        if shape.len() != 3 {
            bail!("expected (c, h, w) images, got {shape:?}");
        }
        for im in imgs {
            if im.shape != *shape {
                bail!(
                    "ragged image shapes in batch: {:?} vs {:?}",
                    im.shape, shape
                );
            }
        }
        let b = imgs.len();
        let mut data = Vec::with_capacity(b * imgs[0].numel());
        for im in imgs {
            data.extend_from_slice(&im.data);
        }
        let mut act = Activation::Image(Tensor::new(
            &[b, shape[0], shape[1], shape[2]],
            data,
        ));
        let first = self.first_linear();
        let stop = first.unwrap_or(self.manifest.layers.len());
        for idx in 0..stop {
            act = self.run_electronic_layer(idx, &self.manifest.layers[idx], act)?;
        }
        let state = match first {
            Some(idx)
                if matches!(self.plans[idx], LayerPlan::Linear(_)) || photonic =>
            {
                let prep = self.prep_linear(
                    idx,
                    &self.manifest.layers[idx],
                    act,
                    photonic,
                    snap,
                )?;
                PreState::Prepped { prep }
            }
            // gemm-arch first linear (digital): no operand prep to hoist
            Some(idx) => PreState::Plain { act, next: idx },
            None => PreState::Plain { act, next: stop },
        };
        Ok(PreBatch { state })
    }

    /// **Chip stage**: consume a [`PreBatch`], run the span from the
    /// first through the last linear layer (the chip-occupying window —
    /// every crossbar pass, plus whatever electronic layers sit between
    /// linears), and hand back the activation for the post stage.  This
    /// is the only stage that touches the backend, so batches streaming
    /// through a pipeline serialize here in FIFO order and the sim's
    /// pass-count drift clock advances exactly as in the sequential path.
    pub fn chip_batch(
        &self,
        pre: PreBatch,
        backend: &mut Backend,
    ) -> Result<MidBatch> {
        // propagate the engine's worker count into the sim's crossbar /
        // Γ-encode kernels (results are bit-identical for any value)
        if let Backend::PhotonicSim(sim) = backend {
            sim.threads = self.threads;
        }
        let (mut act, mut next) = match pre.state {
            PreState::Empty => {
                return Ok(MidBatch { state: MidState::Empty });
            }
            PreState::Plain { act, next } => (act, next),
            PreState::Prepped { prep } => {
                let idx = prep.idx;
                (self.finish_linear(prep, backend)?, idx + 1)
            }
        };
        let stop = self.last_linear().map(|i| i + 1).unwrap_or(next).max(next);
        while next < stop {
            act = self.run_layer(next, &self.manifest.layers[next], act, backend)?;
            next += 1;
        }
        Ok(MidBatch { state: MidState::Act { act, next } })
    }

    /// **Post stage** (electronic, chip-free): run every layer after the
    /// last linear and extract per-image logits.  Like the pre stage it
    /// touches no shared state, so it can overlap the next batch's chip
    /// passes.
    pub fn post_batch(&self, mid: MidBatch) -> Result<Vec<Vec<f32>>> {
        let (mut act, next) = match mid.state {
            MidState::Empty => return Ok(Vec::new()),
            MidState::Act { act, next } => (act, next),
        };
        for idx in next..self.manifest.layers.len() {
            act = self.run_electronic_layer(idx, &self.manifest.layers[idx], act)?;
        }
        match act {
            Activation::Matrix(t) => {
                let n = t.shape[1];
                Ok(t.data.chunks(n).map(|r| r.to_vec()).collect())
            }
            Activation::Image(_) => bail!("network did not end in a vector"),
        }
    }

    fn run_layer(
        &self,
        idx: usize,
        spec: &LayerSpec,
        act: Activation,
        backend: &mut Backend,
    ) -> Result<Activation> {
        match (&self.layers[idx], spec.kind) {
            (LayerState::Linear(wts), LayerKind::Conv) => {
                if matches!(self.plans[idx], LayerPlan::Linear(_))
                    || matches!(backend, Backend::PhotonicSim(_))
                {
                    // circ layers (and every photonic layer — the circ
                    // arch requirement is enforced in prep) run the same
                    // prep/finish pair the staged pipeline uses, so the
                    // in-line and pipelined paths cannot drift apart
                    let photonic = matches!(backend, Backend::PhotonicSim(_));
                    let prep =
                        self.prep_linear(idx, spec, act, photonic, None)?;
                    self.finish_linear(prep, backend)
                } else {
                    // gemm arch on the digital backend: dense multiply,
                    // logical dims
                    let imgs = act.image()?;
                    let (b, h, w) =
                        (imgs.shape[0], imgs.shape[2], imgs.shape[3]);
                    let xm = tensor::im2col_same_batch(&imgs, spec.k);
                    let dense = wts
                        .dense
                        .as_ref()
                        .context("gemm layer without dense weights")?;
                    let y = dense.matmul_par(&xm, self.threads);
                    scratch::put(xm.data);
                    let out = cols_to_images(&y, b, spec.cout, h, w);
                    scratch::put(y.data);
                    Ok(Activation::Image(add_channel_bias_batch(
                        out, &wts.bias,
                    )))
                }
            }
            (LayerState::Linear(wts), LayerKind::Fc) => {
                if matches!(self.plans[idx], LayerPlan::Linear(_))
                    || matches!(backend, Backend::PhotonicSim(_))
                {
                    let photonic = matches!(backend, Backend::PhotonicSim(_));
                    let prep =
                        self.prep_linear(idx, spec, act, photonic, None)?;
                    self.finish_linear(prep, backend)
                } else {
                    let x = act.matrix()?; // (b, n)
                    let b = x.shape[0];
                    let xt = x.transpose2();
                    let y = wts
                        .dense
                        .as_ref()
                        .context("gemm layer without dense weights")?
                        .matmul_par(&xt, self.threads);
                    // keep logical rows, transpose to (b, cout), add bias
                    let m = spec.cout.min(y.shape[0]);
                    let mut out = Tensor::zeros(&[b, m]);
                    for bi in 0..b {
                        for r in 0..m {
                            out.data[bi * m + r] = y.at2(r, bi)
                                + wts.bias.get(r).copied().unwrap_or(0.0);
                        }
                    }
                    scratch::put(y.data);
                    Ok(Activation::Matrix(out))
                }
            }
            _ => self.run_electronic_layer(idx, spec, act),
        }
    }

    /// Pack linear layer `idx`'s operand from the incoming activation:
    /// im2col / transpose, the photonic activation-scale clamp, row
    /// padding to the BCM width, and (given a snapshot) the off-thread
    /// quantize + Γ-mix.  Pure with respect to the backend — this is the
    /// half of a linear layer the pipeline's pre stage hoists.  Also the
    /// shared operand prep of a farm-partitioned layer
    /// ([`crate::farm::PartitionedEngine`]) — every chip's shard multiplies
    /// the same packed operand.
    pub(crate) fn prep_linear(
        &self,
        idx: usize,
        spec: &LayerSpec,
        act: Activation,
        photonic: bool,
        snap: Option<&EncodeSnapshot>,
    ) -> Result<LinearPrep> {
        let (_, lp) = self.linear_plan(idx)?;
        let (xp, shape) = match spec.kind {
            LayerKind::Conv => {
                let imgs = act.image()?;
                let (b, h, w) =
                    (imgs.shape[0], imgs.shape[2], imgs.shape[3]);
                let xm = if photonic {
                    tensor::im2col_same_batch(
                        &imgs.map(|x| {
                            (x / spec.act_scale).clamp(0.0, 1.0)
                        }),
                        spec.k,
                    )
                } else {
                    tensor::im2col_same_batch(&imgs, spec.k)
                };
                if xm.shape[0] != lp.rows {
                    bail!(
                        "layer {idx}: conv operand rows {} != \
                         c·k·k = {} (input channel mismatch)",
                        xm.shape[0],
                        lp.rows
                    );
                }
                (pad_rows_pooled(xm, lp.n_pad), PrepShape::Conv { b, h, w })
            }
            LayerKind::Fc => {
                let x = act.matrix()?; // (b, n)
                let b = x.shape[0];
                let n = x.shape[1];
                if photonic {
                    if n > lp.n_pad {
                        bail!(
                            "layer {idx}: fc input width {n} exceeds \
                             padded BCM width {}",
                            lp.n_pad
                        );
                    }
                    let s = spec.act_scale;
                    let mut xp = Tensor::new(
                        &[lp.n_pad, b],
                        scratch::take(lp.n_pad * b),
                    );
                    for bi in 0..b {
                        for i in 0..n {
                            xp.data[i * b + bi] =
                                (x.at2(bi, i) / s).clamp(0.0, 1.0);
                        }
                    }
                    (xp, PrepShape::Fc { b })
                } else {
                    // the digital path keeps the dense-matmul-era
                    // strictness: exact logical width, no silent
                    // zero-padding of a malformed operand
                    if n != lp.rows {
                        bail!(
                            "layer {idx}: fc input width {n} != \
                             manifest cin {}",
                            lp.rows
                        );
                    }
                    // (m, b): column j is image j, same per-column
                    // accumulation order as the per-image multiply
                    (
                        pad_rows_pooled(x.transpose2(), lp.n_pad),
                        PrepShape::Fc { b },
                    )
                }
            }
            _ => bail!("layer {idx}: prep_linear on a non-linear layer"),
        };
        // optimistic pre-encode: only worth stamping on the planned
        // photonic path (the chip re-validates the generation per pass)
        let enc = match snap {
            Some(snap) if photonic && self.use_plans => {
                Some(snap.encode_operand(&xp, self.threads))
            }
            _ => None,
        };
        Ok(LinearPrep { idx, photonic, xp, enc, shape })
    }

    /// Execute linear layer `idx` from its packed operand: the backend
    /// multiply (the chip's sign-split pass pair on the photonic path,
    /// consuming a still-valid pre-encode if the prep carries one), the
    /// activation rescale, and the reshape + bias back into an
    /// activation.  The only half of a linear layer that touches the
    /// backend — the pipeline's chip stage.
    fn finish_linear(
        &self,
        prep: LinearPrep,
        backend: &mut Backend,
    ) -> Result<Activation> {
        let LinearPrep { idx, photonic, xp, enc, shape } = prep;
        let spec = &self.manifest.layers[idx];
        let wts = match &self.layers[idx] {
            LayerState::Linear(w) => w,
            _ => bail!("layer {idx}: finish_linear on a non-linear layer"),
        };
        let (bcm, lp) = self.linear_plan(idx)?;
        let y = match backend {
            Backend::Digital => {
                if photonic {
                    bail!(
                        "layer {idx}: photonic operand prep handed to a \
                         digital backend"
                    );
                }
                let y = if self.use_plans {
                    lp.multiply(bcm, &xp, self.threads)
                } else {
                    lp.multiply_reference(bcm, &xp)
                };
                scratch::put(xp.data);
                y
            }
            Backend::PhotonicSim(sim) => {
                if !photonic {
                    bail!(
                        "layer {idx}: digital operand prep handed to a \
                         photonic backend"
                    );
                }
                let s = spec.act_scale;
                let y = if self.use_plans {
                    // in-place rescale keeps the pooled buffer (same
                    // op order as the reference's .scale: one extra
                    // multiply per element after the sign fuse)
                    let mut y = sim.forward_signed_planned_enc(
                        self.tile_owner,
                        idx,
                        &lp.sign,
                        &xp,
                        enc.as_ref(),
                    );
                    for v in y.data.iter_mut() {
                        *v *= s;
                    }
                    y
                } else {
                    sim.forward_signed(bcm, &xp).scale(s)
                };
                scratch::put(xp.data);
                y
            }
        };
        if let Some(enc) = enc {
            enc.recycle();
        }
        match shape {
            PrepShape::Conv { b, h, w } => {
                let out = cols_to_images(&y, b, spec.cout, h, w);
                scratch::put(y.data);
                Ok(Activation::Image(add_channel_bias_batch(out, &wts.bias)))
            }
            PrepShape::Fc { b } => {
                // keep logical rows, transpose back to (b, cout), add bias
                let m = spec.cout.min(y.shape[0]);
                let mut out = Tensor::zeros(&[b, m]);
                for bi in 0..b {
                    for r in 0..m {
                        out.data[bi * m + r] = y.at2(r, bi)
                            + wts.bias.get(r).copied().unwrap_or(0.0);
                    }
                }
                scratch::put(y.data);
                Ok(Activation::Matrix(out))
            }
        }
    }

    /// Run a non-linear (chip-free) layer — the arms shared by the pre
    /// and post stages and [`Engine::run_layer`].
    pub(crate) fn run_electronic_layer(
        &self,
        idx: usize,
        spec: &LayerSpec,
        act: Activation,
    ) -> Result<Activation> {
        Ok(match (&self.layers[idx], spec.kind) {
            (LayerState::Bn(bn), LayerKind::Bn) => {
                Activation::Image(tensor::batchnorm_batch(
                    &act.image()?,
                    &bn.mean,
                    &bn.var,
                    &bn.gamma,
                    &bn.beta,
                    1e-5,
                ))
            }
            (_, LayerKind::Relu) => match act {
                Activation::Image(t) => Activation::Image(t.relu()),
                Activation::Matrix(t) => Activation::Matrix(t.relu()),
            },
            (_, LayerKind::Pool) => Activation::Image(
                tensor::maxpool_batch(&act.image()?, spec.pool),
            ),
            (_, LayerKind::Flatten) => {
                let t = act.image()?;
                let (b, per) = (t.shape[0], t.numel() / t.shape[0]);
                Activation::Matrix(t.reshape(&[b, per]))
            }
            (st, k) => bail!(
                "layer {idx}: state/kind mismatch ({k:?} vs {})",
                match st {
                    LayerState::Linear(_) => "linear",
                    LayerState::Bn(_) => "bn",
                    LayerState::Stateless => "stateless",
                }
            ),
        })
    }

    /// The compressed weights + planned state of linear layer `idx`
    /// (photonic execution requires the circ arch).
    pub(crate) fn linear_plan(&self, idx: usize) -> Result<(&Bcm, &LinearPlan)> {
        let bcm = match &self.layers[idx] {
            LayerState::Linear(lw) => lw.bcm.as_ref(),
            _ => None,
        };
        match (bcm, &self.plans[idx]) {
            (Some(bcm), LayerPlan::Linear(lp)) => Ok((bcm, lp)),
            _ => bail!("photonic path needs circ arch"),
        }
    }

    /// Bias vector of linear layer `idx` — the farm's shared reduce step
    /// adds it once, after the per-chip partials are concatenated.
    pub(crate) fn linear_bias(&self, idx: usize) -> Result<&[f32]> {
        match &self.layers[idx] {
            LayerState::Linear(lw) => Ok(&lw.bias),
            _ => bail!("layer {idx}: linear_bias on a non-linear layer"),
        }
    }
}

/// Output of [`Engine::pre_batch`]: a validated, packed batch with the
/// prefix layers run and (when the network leads with a planned linear)
/// the first linear's operand packed — everything that can happen before
/// the backend is needed.  Opaque hand-off token between the pre and
/// chip stages; plain owned tensors, so it crosses threads freely.
pub struct PreBatch {
    pub(crate) state: PreState,
}

pub(crate) enum PreState {
    /// empty input batch: flows through to empty logits
    Empty,
    /// prefix ran; the chip stage resumes the layer walk at `next`
    /// (either the network has no planned first linear or none at all)
    Plain { act: Activation, next: usize },
    /// prefix ran and the first linear's operand is packed (and possibly
    /// pre-encoded against an [`EncodeSnapshot`])
    Prepped { prep: LinearPrep },
}

/// Output of [`Engine::chip_batch`]: the activation after the last
/// linear layer, ready for the chip-free post stage.
pub struct MidBatch {
    pub(crate) state: MidState,
}

pub(crate) enum MidState {
    Empty,
    Act { act: Activation, next: usize },
}

/// A linear layer's packed operand, between prep and execution.
pub(crate) struct LinearPrep {
    pub(crate) idx: usize,
    /// packed for the photonic path (activation-scale clamp applied)?
    /// Must match the backend handed to [`Engine::finish_linear`].
    pub(crate) photonic: bool,
    pub(crate) xp: Tensor,
    /// optimistic off-thread operand encode, generation-stamped; the
    /// chip re-validates per pass and falls back to in-line encoding
    pub(crate) enc: Option<EncodedOperand>,
    pub(crate) shape: PrepShape,
}

pub(crate) enum PrepShape {
    Conv { b: usize, h: usize, w: usize },
    Fc { b: usize },
}

/// Batch-major activation flowing between layers: the whole batch rides in
/// one tensor so every linear layer sees a single multi-column operand.
pub(crate) enum Activation {
    /// image batch, shape (b, c, h, w)
    Image(Tensor),
    /// flattened feature batch, shape (b, n), one row per image
    Matrix(Tensor),
}

impl Activation {
    pub(crate) fn image(self) -> Result<Tensor> {
        match self {
            Activation::Image(t) => Ok(t),
            Activation::Matrix(_) => bail!("expected image activation"),
        }
    }

    /// Row-per-image matrix view; images flatten to their row-major data.
    pub(crate) fn matrix(self) -> Result<Tensor> {
        match self {
            Activation::Matrix(t) => Ok(t),
            Activation::Image(t) => {
                let (b, per) = (t.shape[0], t.numel() / t.shape[0]);
                Ok(t.reshape(&[b, per]))
            }
        }
    }
}

/// Scatter a (rows, b·h·w) column-block back into a (b, keep, h, w) image
/// batch, keeping the first `keep` logical rows (the BCM may be row-padded).
/// Shared with the training forward pass ([`crate::train`]).
pub(crate) fn cols_to_images(
    y: &Tensor,
    b: usize,
    keep: usize,
    h: usize,
    w: usize,
) -> Tensor {
    let hw = h * w;
    let total = y.shape[1];
    debug_assert_eq!(total, b * hw);
    let mut out = Tensor::zeros(&[b, keep, h, w]);
    for bi in 0..b {
        for ch in 0..keep {
            let src = &y.data[ch * total + bi * hw..ch * total + (bi + 1) * hw];
            let dst = (bi * keep + ch) * hw;
            out.data[dst..dst + hw].copy_from_slice(src);
        }
    }
    out
}

pub(crate) fn add_channel_bias_batch(mut t: Tensor, bias: &[f32]) -> Tensor {
    let (b, c) = (t.shape[0], t.shape[1]);
    let hw = t.shape[2] * t.shape[3];
    for bi in 0..b {
        for ci in 0..c.min(bias.len()) {
            let off = (bi * c + ci) * hw;
            for v in &mut t.data[off..off + hw] {
                *v += bias[ci];
            }
        }
    }
    t
}

/// Zero-pad the rows of an (n, cols) operand block up to the BCM's padded
/// input width `n_pad` — padded rows meet zero weight columns, so the
/// product is unchanged.  Hot-path form: consumes the operand, draws the
/// padded block from the thread-local scratch arena (recycling the
/// input's buffer), and forwards the operand untouched when no padding is
/// needed instead of cloning it.  Shared by the photonic serving path and
/// the training forward pass ([`crate::train`]).
pub(crate) fn pad_rows_pooled(x: Tensor, n_pad: usize) -> Tensor {
    if x.shape[0] == n_pad {
        return x;
    }
    assert!(x.shape[0] < n_pad, "operand taller than padded BCM width");
    let cols = x.shape[1];
    let mut buf = scratch::take(n_pad * cols);
    buf[..x.shape[0] * cols].copy_from_slice(&x.data);
    scratch::put(x.data);
    Tensor::new(&[n_pad, cols], buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::ChipDescription;
    use crate::util::rng::Rng;

    /// Build a tiny 2-layer circ model entirely in memory.
    fn tiny_engine() -> Engine {
        let manifest = Manifest::parse(
            r#"{
              "dataset": "synth_cxr", "classes": 3,
              "layers": [
                {"kind": "conv", "cin": 1, "cout": 4, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0},
                {"kind": "relu", "cin": 0, "cout": 0, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0},
                {"kind": "pool", "cin": 0, "cout": 0, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0},
                {"kind": "flatten", "cin": 0, "cout": 0, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0},
                {"kind": "fc", "cin": 64, "cout": 3, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0}
              ]}"#,
        )
        .unwrap();
        let mut bundle = Bundle::default();
        let mut rng = Rng::new(42);
        // conv: cout 4 -> P=1, n=9 -> Q=3
        let mut w0 = vec![0.0f32; 3 * 4];
        rng.fill_uniform(&mut w0);
        for v in w0.iter_mut() {
            *v = (*v - 0.5) * 0.5;
        }
        bundle.insert_f32("layer0.w", &[1, 3, 4], w0);
        bundle.insert_f32("layer0.b", &[4], vec![0.0; 4]);
        // fc: 64 -> 3: P=1 (pad to 4), Q=16
        let mut w4 = vec![0.0f32; 16 * 4];
        rng.fill_uniform(&mut w4);
        for v in w4.iter_mut() {
            *v = (*v - 0.5) * 0.2;
        }
        bundle.insert_f32("layer4.w", &[1, 16, 4], w4);
        bundle.insert_f32("layer4.b", &[3], vec![0.1, 0.2, 0.3]);
        Engine::from_parts(manifest, &bundle).unwrap()
    }

    fn input() -> Tensor {
        let mut rng = Rng::new(7);
        let mut d = vec![0.0f32; 8 * 8];
        rng.fill_uniform(&mut d);
        Tensor::new(&[1, 8, 8], d)
    }

    #[test]
    fn digital_forward_shape() {
        let e = tiny_engine();
        let y = e.forward(&input(), &mut Backend::Digital).unwrap();
        assert_eq!(y.len(), 3);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn photonic_ideal_matches_digital() {
        let e = tiny_engine();
        let y_dig = e.forward(&input(), &mut Backend::Digital).unwrap();
        let sim = ChipSim::deterministic(ChipDescription::ideal(4));
        let y_pho = e
            .forward(&input(), &mut Backend::PhotonicSim(sim))
            .unwrap();
        for (a, b) in y_dig.iter().zip(&y_pho) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn photonic_nonideal_differs_but_finite() {
        let e = tiny_engine();
        let mut desc = ChipDescription::ideal(4);
        desc.w_bits = 6;
        desc.x_bits = 4;
        desc.dark = 0.015;
        let sim = ChipSim::deterministic(desc);
        let y = e
            .forward(&input(), &mut Backend::PhotonicSim(sim))
            .unwrap();
        let y_dig = e.forward(&input(), &mut Backend::Digital).unwrap();
        assert!(y.iter().all(|v| v.is_finite()));
        let diff: f32 = y
            .iter()
            .zip(&y_dig)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(diff > 1e-6, "quantization must perturb outputs");
    }

    #[test]
    fn batch_forward_consistent() {
        let e = tiny_engine();
        let imgs = vec![input(), input()];
        let ys = e.forward_batch(&imgs, &mut Backend::Digital).unwrap();
        assert_eq!(ys.len(), 2);
        assert_eq!(ys[0], ys[1]);
    }

    fn distinct_inputs(n: usize) -> Vec<Tensor> {
        (0..n)
            .map(|i| {
                let mut rng = Rng::new(100 + i as u64);
                let mut d = vec![0.0f32; 8 * 8];
                rng.fill_uniform(&mut d);
                Tensor::new(&[1, 8, 8], d)
            })
            .collect()
    }

    #[test]
    fn batched_digital_identical_to_per_image() {
        let e = tiny_engine();
        let imgs = distinct_inputs(5);
        let batched = e.forward_batch(&imgs, &mut Backend::Digital).unwrap();
        for (im, row) in imgs.iter().zip(&batched) {
            let single = e.forward(im, &mut Backend::Digital).unwrap();
            assert_eq!(&single, row, "batched digital must be bit-identical");
        }
    }

    #[test]
    fn batched_photonic_identical_to_per_image() {
        let e = tiny_engine();
        let mut desc = ChipDescription::ideal(4);
        desc.w_bits = 6;
        desc.x_bits = 4;
        desc.dark = 0.015;
        let imgs = distinct_inputs(4);
        let mut be_batch =
            Backend::PhotonicSim(ChipSim::deterministic(desc.clone()));
        let batched = e.forward_batch(&imgs, &mut be_batch).unwrap();
        for (im, row) in imgs.iter().zip(&batched) {
            let mut be_one =
                Backend::PhotonicSim(ChipSim::deterministic(desc.clone()));
            let single = e.forward(im, &mut be_one).unwrap();
            assert_eq!(&single, row, "batched photonic must be bit-identical");
        }
    }

    #[test]
    fn forward_batch_empty_is_empty() {
        let e = tiny_engine();
        let ys = e.forward_batch(&[], &mut Backend::Digital).unwrap();
        assert!(ys.is_empty());
    }

    #[test]
    fn forward_batch_rejects_ragged_shapes() {
        let e = tiny_engine();
        let imgs = vec![input(), Tensor::zeros(&[1, 4, 4])];
        assert!(e.forward_batch(&imgs, &mut Backend::Digital).is_err());
    }

    #[test]
    fn chip_passes_counted() {
        let e = tiny_engine();
        let sim = ChipSim::deterministic(ChipDescription::ideal(4));
        let mut be = Backend::PhotonicSim(sim);
        e.forward(&input(), &mut be).unwrap();
        if let Backend::PhotonicSim(sim) = &be {
            // two linear layers × 2 sign-split passes
            assert_eq!(sim.passes(), 4);
        }
    }

    #[test]
    fn planned_engine_is_bit_identical_to_reference_paths() {
        let planned = tiny_engine();
        let mut reference = tiny_engine();
        reference.use_plans = false;
        let imgs = distinct_inputs(4);
        let a = planned
            .forward_batch(&imgs, &mut Backend::Digital)
            .unwrap();
        let b = reference
            .forward_batch(&imgs, &mut Backend::Digital)
            .unwrap();
        assert_eq!(a, b, "digital planned path must match the reference");
        let mut desc = ChipDescription::ideal(4);
        desc.w_bits = 6;
        desc.x_bits = 4;
        desc.dark = 0.015;
        let mut be_p =
            Backend::PhotonicSim(ChipSim::deterministic(desc.clone()));
        let mut be_r = Backend::PhotonicSim(ChipSim::deterministic(desc));
        let yp = planned.forward_batch(&imgs, &mut be_p).unwrap();
        let yr = reference.forward_batch(&imgs, &mut be_r).unwrap();
        assert_eq!(yp, yr, "photonic planned path must match the reference");
    }

    #[test]
    fn planned_engine_encodes_each_tile_once_per_chip() {
        let e = tiny_engine();
        let mut desc = ChipDescription::ideal(4);
        desc.w_bits = 6;
        let mut be = Backend::PhotonicSim(ChipSim::deterministic(desc));
        let imgs = distinct_inputs(3);
        for _ in 0..5 {
            e.forward_batch(&imgs, &mut be).unwrap();
        }
        if let Backend::PhotonicSim(sim) = &be {
            // 2 linear layers × 2 sign halves, encoded once — not per batch
            assert_eq!(sim.encodes_done, 4);
            assert_eq!(sim.cached_tiles(), 4);
        }
    }

    #[test]
    fn staged_pre_chip_post_composes_to_forward_batch() {
        // the stage split IS the sequential path; running the stages by
        // hand must reproduce forward_batch exactly on both backends
        let e = tiny_engine();
        let imgs = distinct_inputs(4);
        let want_dig =
            e.forward_batch(&imgs, &mut Backend::Digital).unwrap();
        let pre = e.pre_batch(&imgs, false, None).unwrap();
        let mut be = Backend::Digital;
        let mid = e.chip_batch(pre, &mut be).unwrap();
        assert_eq!(e.post_batch(mid).unwrap(), want_dig);
        let mut desc = ChipDescription::ideal(4);
        desc.w_bits = 6;
        desc.x_bits = 4;
        desc.dark = 0.015;
        let want_pho = e
            .forward_batch(
                &imgs,
                &mut Backend::PhotonicSim(ChipSim::deterministic(
                    desc.clone(),
                )),
            )
            .unwrap();
        let pre = e.pre_batch(&imgs, true, None).unwrap();
        let mut be = Backend::PhotonicSim(ChipSim::deterministic(desc));
        let mid = e.chip_batch(pre, &mut be).unwrap();
        assert_eq!(e.post_batch(mid).unwrap(), want_pho);
        // and the empty batch flows through the stages to empty logits
        let pre = e.pre_batch(&[], true, None).unwrap();
        let mid = e.chip_batch(pre, &mut be).unwrap();
        assert!(e.post_batch(mid).unwrap().is_empty());
    }

    #[test]
    fn pre_encoded_first_linear_is_bit_identical_and_engages() {
        let e = tiny_engine();
        let mut desc = ChipDescription::ideal(4);
        desc.w_bits = 6;
        desc.x_bits = 4;
        desc.dark = 0.015;
        let imgs = distinct_inputs(3);
        let want = e
            .forward_batch(
                &imgs,
                &mut Backend::PhotonicSim(ChipSim::deterministic(
                    desc.clone(),
                )),
            )
            .unwrap();
        let mut be = Backend::PhotonicSim(ChipSim::deterministic(desc));
        let snap = match &be {
            Backend::PhotonicSim(sim) => sim.encode_snapshot(),
            Backend::Digital => unreachable!(),
        };
        let pre = e.pre_batch(&imgs, true, Some(&snap)).unwrap();
        let mid = e.chip_batch(pre, &mut be).unwrap();
        assert_eq!(e.post_batch(mid).unwrap(), want);
        if let Backend::PhotonicSim(sim) = &be {
            assert_eq!(
                sim.pre_hits, 2,
                "first linear's sign pair must consume the pre-encode"
            );
            assert_eq!(sim.pre_stale, 0);
        }
    }

    #[test]
    fn chip_passes_flat_across_batch_tiles_scale() {
        // the point of batch-major execution: a batch of any width costs
        // the same 2 sign-split passes per linear layer, while tile count
        // grows with the streamed columns
        let e = tiny_engine();
        let sim = ChipSim::deterministic(ChipDescription::ideal(4));
        let mut be = Backend::PhotonicSim(sim);
        let imgs = distinct_inputs(6);
        e.forward_batch(&imgs, &mut be).unwrap();
        if let Backend::PhotonicSim(sim) = &be {
            assert_eq!(sim.passes(), 4, "2 linear layers × 2 passes, b=6");
            // conv: P=1,Q=3 over 6·64 columns; fc: P=1,Q=16 over 6 columns
            let conv_tiles = 2 * 3 * (6 * 64);
            let fc_tiles = 2 * 16 * 6;
            assert_eq!(sim.tiles_executed, (conv_tiles + fc_tiles) as u64);
        }
    }
}
