//! Layer-graph execution: digital fp32 or photonic-simulated CirPTC.

use std::path::Path;

use crate::bail;
use crate::circulant::Bcm;
use crate::data::Bundle;
use crate::simulator::ChipSim;
use crate::tensor::{self, Tensor};
use crate::util::error::{Context, Result};

use super::manifest::{LayerKind, LayerSpec, Manifest};

/// Execution backend for conv/FC layers.
#[derive(Debug)]
pub enum Backend {
    /// fp32 dense math (expansion of compressed weights)
    Digital,
    /// every linear layer streamed through the CirPTC simulator as
    /// sign-split positive-only BCM tiles (paper lookup-mode inference)
    PhotonicSim(ChipSim),
}

fn ceil_to(x: usize, m: usize) -> usize {
    (x + m - 1) / m * m
}

/// Weights of one linear layer in both representations.
struct LinearWeights {
    /// compressed BCM (circ arch) — padded dims (P·l ≥ cout, Q·l ≥ n)
    bcm: Option<Bcm>,
    /// dense (m, n) weight (gemm arch, or the expansion cache for circ)
    dense: Tensor,
    bias: Vec<f32>,
}

struct BnWeights {
    gamma: Vec<f32>,
    beta: Vec<f32>,
    mean: Vec<f32>,
    var: Vec<f32>,
}

enum LayerState {
    Linear(LinearWeights),
    Bn(BnWeights),
    Stateless,
}

/// A loaded StrC-ONN ready to execute.
pub struct Engine {
    pub manifest: Manifest,
    layers: Vec<LayerState>,
}

impl Engine {
    /// Load manifest + weight bundle (as exported by `compile.train`).
    pub fn load(manifest_path: &Path, bundle_path: &Path) -> Result<Engine> {
        let manifest = Manifest::load(manifest_path)?;
        let bundle = Bundle::load(bundle_path)?;
        Engine::from_parts(manifest, &bundle)
    }

    pub fn from_parts(manifest: Manifest, bundle: &Bundle) -> Result<Engine> {
        let mut layers = Vec::with_capacity(manifest.layers.len());
        for (i, spec) in manifest.layers.iter().enumerate() {
            let name = format!("layer{i}");
            let state = match spec.kind {
                LayerKind::Conv | LayerKind::Fc => {
                    let n_in = if spec.kind == LayerKind::Conv {
                        spec.cin * spec.k * spec.k
                    } else {
                        spec.cin
                    };
                    let w = bundle.get(&format!("{name}.w"))?;
                    let bias =
                        bundle.get(&format!("{name}.b"))?.as_f32()?.to_vec();
                    if spec.arch == "circ" {
                        let (p, q) = (
                            ceil_to(spec.cout, spec.l) / spec.l,
                            ceil_to(n_in, spec.l) / spec.l,
                        );
                        let data = w.as_f32()?;
                        if w.shape() != [p, q, spec.l] {
                            bail!(
                                "{name}.w shape {:?}, expected [{p},{q},{}]",
                                w.shape(), spec.l
                            );
                        }
                        let bcm =
                            Bcm::new(p, q, spec.l, data.to_vec());
                        // dense expansion sliced to logical dims, cached
                        // for the digital path
                        let full = bcm.expand();
                        let mut dense =
                            Tensor::zeros(&[spec.cout, n_in]);
                        for r in 0..spec.cout {
                            for c in 0..n_in {
                                dense.set2(r, c, full.at2(r, c));
                            }
                        }
                        LayerState::Linear(LinearWeights {
                            bcm: Some(bcm),
                            dense,
                            bias,
                        })
                    } else {
                        let data = w.as_f32()?.to_vec();
                        LayerState::Linear(LinearWeights {
                            bcm: None,
                            dense: Tensor::new(&[spec.cout, n_in], data),
                            bias,
                        })
                    }
                }
                LayerKind::Bn => LayerState::Bn(BnWeights {
                    gamma: bundle.get(&format!("{name}.gamma"))?.as_f32()?.to_vec(),
                    beta: bundle.get(&format!("{name}.beta"))?.as_f32()?.to_vec(),
                    mean: bundle
                        .get(&format!("{name}.state.mean"))?
                        .as_f32()?
                        .to_vec(),
                    var: bundle
                        .get(&format!("{name}.state.var"))?
                        .as_f32()?
                        .to_vec(),
                }),
                _ => LayerState::Stateless,
            };
            layers.push(state);
        }
        Ok(Engine { manifest, layers })
    }

    /// Forward one image (c, h, w) → logits.
    pub fn forward(&self, img: &Tensor, backend: &mut Backend) -> Result<Vec<f32>> {
        let mut act = Activation::Image(img.clone());
        for (i, spec) in self.manifest.layers.iter().enumerate() {
            act = self.run_layer(i, spec, act, backend)?;
        }
        match act {
            Activation::Vector(v) => Ok(v),
            Activation::Image(_) => bail!("network did not end in a vector"),
        }
    }

    /// Forward a batch; returns (batch, classes) logits row-major.
    pub fn forward_batch(
        &self,
        imgs: &[Tensor],
        backend: &mut Backend,
    ) -> Result<Vec<Vec<f32>>> {
        imgs.iter().map(|im| self.forward(im, backend)).collect()
    }

    fn run_layer(
        &self,
        idx: usize,
        spec: &LayerSpec,
        act: Activation,
        backend: &mut Backend,
    ) -> Result<Activation> {
        Ok(match (&self.layers[idx], spec.kind) {
            (LayerState::Linear(wts), LayerKind::Conv) => {
                let img = act.image()?;
                let y = match backend {
                    Backend::Digital => {
                        tensor::conv2d(&img, &wts.dense, spec.k, true)
                    }
                    Backend::PhotonicSim(sim) => {
                        photonic_conv(sim, wts, spec, &img)?
                    }
                };
                Activation::Image(add_channel_bias(y, &wts.bias))
            }
            (LayerState::Linear(wts), LayerKind::Fc) => {
                let v = act.vector()?;
                let y = match backend {
                    Backend::Digital => {
                        let x = Tensor::new(&[v.len(), 1], v);
                        let out = wts.dense.matmul(&x);
                        out.data
                    }
                    Backend::PhotonicSim(sim) => {
                        photonic_fc(sim, wts, spec, &v)?
                    }
                };
                Activation::Vector(
                    y.iter().zip(&wts.bias).map(|(a, b)| a + b).collect(),
                )
            }
            (LayerState::Bn(bn), LayerKind::Bn) => {
                let img = act.image()?;
                Activation::Image(tensor::batchnorm(
                    &img, &bn.mean, &bn.var, &bn.gamma, &bn.beta, 1e-5,
                ))
            }
            (_, LayerKind::Relu) => match act {
                Activation::Image(t) => Activation::Image(t.relu()),
                Activation::Vector(v) => Activation::Vector(
                    v.into_iter().map(|x| x.max(0.0)).collect(),
                ),
            },
            (_, LayerKind::Pool) => {
                Activation::Image(tensor::maxpool(&act.image()?, spec.pool))
            }
            (_, LayerKind::Flatten) => {
                Activation::Vector(act.image()?.data)
            }
            (st, k) => bail!(
                "layer {idx}: state/kind mismatch ({k:?} vs {})",
                match st {
                    LayerState::Linear(_) => "linear",
                    LayerState::Bn(_) => "bn",
                    LayerState::Stateless => "stateless",
                }
            ),
        })
    }
}

enum Activation {
    Image(Tensor),
    Vector(Vec<f32>),
}

impl Activation {
    fn image(self) -> Result<Tensor> {
        match self {
            Activation::Image(t) => Ok(t),
            Activation::Vector(_) => bail!("expected image activation"),
        }
    }

    fn vector(self) -> Result<Vec<f32>> {
        match self {
            Activation::Vector(v) => Ok(v),
            Activation::Image(t) => Ok(t.data),
        }
    }
}

fn add_channel_bias(mut img: Tensor, bias: &[f32]) -> Tensor {
    let (c, h, w) = (img.shape[0], img.shape[1], img.shape[2]);
    for ci in 0..c.min(bias.len()) {
        for v in &mut img.data[ci * h * w..(ci + 1) * h * w] {
            *v += bias[ci];
        }
    }
    img
}

/// Conv layer on the simulated chip: clip to the device dynamic range,
/// im2col, zero-pad to the BCM's padded input dim, sign-split BCM matmul
/// on chip, rescale, keep the logical output rows (paper Fig. 1a flow).
fn photonic_conv(
    sim: &mut ChipSim,
    wts: &LinearWeights,
    spec: &LayerSpec,
    img: &Tensor,
) -> Result<Tensor> {
    let bcm = wts.bcm.as_ref().context("photonic path needs circ arch")?;
    let s = spec.act_scale;
    let clipped = img.map(|x| (x / s).clamp(0.0, 1.0));
    let xm = tensor::im2col_same(&clipped, spec.k);
    let cols = xm.shape[1];
    let n_pad = bcm.n();
    let mut xp = Tensor::zeros(&[n_pad, cols]);
    xp.data[..xm.shape[0] * cols].copy_from_slice(&xm.data);
    let y = sim.forward_signed(bcm, &xp).scale(s);
    // keep logical rows [0, cout)
    let (h, w) = (img.shape[1], img.shape[2]);
    let mut out = Tensor::zeros(&[spec.cout, h, w]);
    out.data
        .copy_from_slice(&y.data[..spec.cout * cols]);
    Ok(out)
}

/// FC layer on the simulated chip (same pipeline, single column).
fn photonic_fc(
    sim: &mut ChipSim,
    wts: &LinearWeights,
    spec: &LayerSpec,
    v: &[f32],
) -> Result<Vec<f32>> {
    let bcm = wts.bcm.as_ref().context("photonic path needs circ arch")?;
    let s = spec.act_scale;
    let n_pad = bcm.n();
    let mut xp = Tensor::zeros(&[n_pad, 1]);
    for (i, &x) in v.iter().enumerate() {
        xp.data[i] = (x / s).clamp(0.0, 1.0);
    }
    let y = sim.forward_signed(bcm, &xp).scale(s);
    Ok(y.data[..spec.cout].to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::ChipDescription;
    use crate::util::rng::Rng;

    /// Build a tiny 2-layer circ model entirely in memory.
    fn tiny_engine() -> Engine {
        let manifest = Manifest::parse(
            r#"{
              "dataset": "synth_cxr", "classes": 3,
              "layers": [
                {"kind": "conv", "cin": 1, "cout": 4, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0},
                {"kind": "relu", "cin": 0, "cout": 0, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0},
                {"kind": "pool", "cin": 0, "cout": 0, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0},
                {"kind": "flatten", "cin": 0, "cout": 0, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0},
                {"kind": "fc", "cin": 64, "cout": 3, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0}
              ]}"#,
        )
        .unwrap();
        let mut bundle = Bundle::default();
        let mut rng = Rng::new(42);
        // conv: cout 4 -> P=1, n=9 -> Q=3
        let mut w0 = vec![0.0f32; 1 * 3 * 4];
        rng.fill_uniform(&mut w0);
        for v in w0.iter_mut() {
            *v = (*v - 0.5) * 0.5;
        }
        bundle.insert_f32("layer0.w", &[1, 3, 4], w0);
        bundle.insert_f32("layer0.b", &[4], vec![0.0; 4]);
        // fc: 64 -> 3: P=1 (pad to 4), Q=16
        let mut w4 = vec![0.0f32; 1 * 16 * 4];
        rng.fill_uniform(&mut w4);
        for v in w4.iter_mut() {
            *v = (*v - 0.5) * 0.2;
        }
        bundle.insert_f32("layer4.w", &[1, 16, 4], w4);
        bundle.insert_f32("layer4.b", &[3], vec![0.1, 0.2, 0.3]);
        Engine::from_parts(manifest, &bundle).unwrap()
    }

    fn input() -> Tensor {
        let mut rng = Rng::new(7);
        let mut d = vec![0.0f32; 8 * 8];
        rng.fill_uniform(&mut d);
        Tensor::new(&[1, 8, 8], d)
    }

    #[test]
    fn digital_forward_shape() {
        let e = tiny_engine();
        let y = e.forward(&input(), &mut Backend::Digital).unwrap();
        assert_eq!(y.len(), 3);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn photonic_ideal_matches_digital() {
        let e = tiny_engine();
        let y_dig = e.forward(&input(), &mut Backend::Digital).unwrap();
        let sim = ChipSim::deterministic(ChipDescription::ideal(4));
        let y_pho = e
            .forward(&input(), &mut Backend::PhotonicSim(sim))
            .unwrap();
        for (a, b) in y_dig.iter().zip(&y_pho) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn photonic_nonideal_differs_but_finite() {
        let e = tiny_engine();
        let mut desc = ChipDescription::ideal(4);
        desc.w_bits = 6;
        desc.x_bits = 4;
        desc.dark = 0.015;
        let sim = ChipSim::deterministic(desc);
        let y = e
            .forward(&input(), &mut Backend::PhotonicSim(sim))
            .unwrap();
        let y_dig = e.forward(&input(), &mut Backend::Digital).unwrap();
        assert!(y.iter().all(|v| v.is_finite()));
        let diff: f32 = y
            .iter()
            .zip(&y_dig)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(diff > 1e-6, "quantization must perturb outputs");
    }

    #[test]
    fn batch_forward_consistent() {
        let e = tiny_engine();
        let imgs = vec![input(), input()];
        let ys = e.forward_batch(&imgs, &mut Backend::Digital).unwrap();
        assert_eq!(ys.len(), 2);
        assert_eq!(ys[0], ys[1]);
    }

    #[test]
    fn chip_passes_counted() {
        let e = tiny_engine();
        let sim = ChipSim::deterministic(ChipDescription::ideal(4));
        let mut be = Backend::PhotonicSim(sim);
        e.forward(&input(), &mut be).unwrap();
        if let Backend::PhotonicSim(sim) = &be {
            // two linear layers × 2 sign-split passes
            assert_eq!(sim.passes(), 4);
        }
    }
}
