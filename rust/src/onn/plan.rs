//! Planned execution state built once at [`crate::onn::Engine::from_parts`]
//! time (DESIGN.md §perf).
//!
//! Everything here is invariant between weight changes, so it is hoisted
//! out of the per-batch loop:
//!
//! * the layer's **sign split** ([`crate::circulant::SignSplit`]) — the
//!   positive-only halves the chip programs, previously recomputed on
//!   every pass pair;
//! * the shared **FFT plan** + **weight spectra**
//!   ([`fft::plan_for`] / [`fft::WeightSpectra`]) for layers past the
//!   direct-vs-Eq.(2) crossover;
//! * the **operand geometry** (im2col row count, padded BCM width) so
//!   shapes are asserted rather than re-derived per batch;
//! * the **tile-owner id** ([`next_tile_owner`]) keying this engine's
//!   pre-encoded tiles in each worker's [`crate::simulator::ChipSim`]
//!   cache — an [`crate::drift::EngineSlot`] hot swap builds a new
//!   engine, hence a new owner, hence every old tile misses.
//!
//! The planned path is bit-identical to the unplanned reference (the
//! free functions in [`crate::circulant::fft`] and
//! [`crate::simulator::ChipSim::forward_signed`]); `Engine::use_plans =
//! false` re-routes the whole engine through the reference calls so the
//! propcheck suite can pin the equivalence end to end.
//!
//! **Stage-pipeline contract** ([`crate::coordinator::pipeline`]): plans
//! are immutable after `Engine::from_parts`, so the pre / chip / post
//! lanes of the pipelined worker all read them through the same
//! `Arc<Engine>` without locks — the pre lane packs operands against the
//! plan geometry (`rows`, `n_pad`) and pre-encodes against a chip
//! snapshot while the chip lane streams the previous batch.  The
//! generation stamp on the snapshot (plus the tile-owner id above) is
//! what keeps that speculation safe: a drift tick or hot swap simply
//! invalidates the stamp and the chip re-encodes inline.  `LinearPlan`
//! being `Sync` is load-bearing; `tests::plans_are_shareable_across_
//! stage_lanes` turns a regression into a compile error.

use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::Arc;

use crate::circulant::{fft, Bcm, SignSplit};
use crate::tensor::Tensor;

static NEXT_OWNER: AtomicU64 = AtomicU64::new(1);

/// A fresh id for an encode-cache owner (an engine instance, or a drift
/// monitor's probe tile).  Monotonic per process; never reused, so a
/// retired owner's cached tiles can never be served again.
pub fn next_tile_owner() -> u64 {
    NEXT_OWNER.fetch_add(1, Ordering::Relaxed)
}

/// Per-layer plan, aligned with the engine's layer stack.
pub(crate) enum LayerPlan {
    /// circ-arch linear layer (conv / fc)
    Linear(LinearPlan),
    /// anything else (stateless layers, bn, gemm-arch linear)
    Other,
}

/// Cached FFT route state: one shared plan per block length, spectra
/// computed from the layer's weights at engine-build time.
pub(crate) struct FftPlanned {
    pub plan: Arc<fft::FftPlan>,
    pub spec: fft::WeightSpectra,
}

pub(crate) struct LinearPlan {
    /// positive/negative halves + rescale, split once
    pub sign: SignSplit,
    /// padded BCM input width (`Q·l`)
    pub n_pad: usize,
    /// logical operand rows: `c·k·k` im2col rows (conv) or `n_in` (fc)
    pub rows: usize,
    /// `Some` when the crossover picks the Eq. (2) route for this order
    pub fft: Option<FftPlanned>,
}

impl LinearPlan {
    pub fn new(bcm: &Bcm, rows: usize) -> LinearPlan {
        let fft_state = if fft::use_fft_path(bcm.l) {
            let plan = fft::plan_for(bcm.l);
            let spec = fft::WeightSpectra::new(bcm, &plan);
            Some(FftPlanned { plan, spec })
        } else {
            None
        };
        LinearPlan { sign: SignSplit::of(bcm), n_pad: bcm.n(), rows, fft: fft_state }
    }

    /// Planned multiply for the digital path: cached-spectra Eq. (2)
    /// (threaded) past the crossover, the threaded direct kernel below
    /// it.  Bit-identical to [`LinearPlan::multiply_reference`].
    pub fn multiply(&self, bcm: &Bcm, x: &Tensor, threads: usize) -> Tensor {
        match &self.fft {
            Some(f) => fft::bcm_mmm_fft_planned(bcm, x, &f.plan, &f.spec, threads),
            None => bcm.mmm(x, threads),
        }
    }

    /// Unplanned reference twin of [`LinearPlan::multiply`]: same route
    /// choice, per-call plan/spectra rebuild, serial kernels — the PR-4
    /// baseline the benches compare against.
    pub fn multiply_reference(&self, bcm: &Bcm, x: &Tensor) -> Tensor {
        match &self.fft {
            Some(_) => bcm.mmm_fft(x),
            None => bcm.matmul(x),
        }
    }

    /// Block-row shard `[r0, r1)` of this plan for one farm chip
    /// ([`crate::farm`]): the sliced weights plus a plan whose sign halves
    /// are *sliced from the parent split*, keeping the parent's global
    /// rescale.  Re-splitting the sliced weights would pick a shard-local
    /// scale and break the farm's bit-identity with the single-chip
    /// engine whenever the layer's max-magnitude weight lives outside the
    /// shard.  The FFT route decision is inherited (same `l`), with
    /// spectra rebuilt over the sliced rows, so each shard takes the same
    /// direct-vs-Eq.(2) route as the full layer.
    pub fn shard_of(&self, bcm: &Bcm, r0: usize, r1: usize) -> (Bcm, LinearPlan) {
        let shard = bcm.block_rows(r0, r1);
        let fft_state = self.fft.as_ref().map(|f| FftPlanned {
            plan: Arc::clone(&f.plan),
            spec: fft::WeightSpectra::new(&shard, &f.plan),
        });
        let sign = SignSplit {
            pos: self.sign.pos.block_rows(r0, r1),
            neg: self.sign.neg.block_rows(r0, r1),
            scale: self.sign.scale,
        };
        let plan = LinearPlan { sign, n_pad: self.n_pad, rows: self.rows, fft: fft_state };
        (shard, plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_bcm(p: usize, q: usize, l: usize, seed: u64) -> Bcm {
        let mut r = Rng::new(seed);
        let mut w = vec![0.0f32; p * q * l];
        r.fill_uniform(&mut w);
        Bcm::new(p, q, l, w)
    }

    #[test]
    fn plans_are_shareable_across_stage_lanes() {
        // the pipelined worker's pre and chip lanes read the same
        // Arc<Engine> (hence the same LinearPlan) concurrently; this
        // fails to compile if a plan field ever loses Send + Sync
        fn assert_lane_shareable<T: Send + Sync>() {}
        assert_lane_shareable::<LinearPlan>();
        assert_lane_shareable::<LayerPlan>();
    }

    #[test]
    fn owners_are_unique_and_monotonic() {
        let a = next_tile_owner();
        let b = next_tile_owner();
        assert!(b > a);
    }

    #[test]
    fn plan_routes_by_crossover() {
        // order 4: direct; order 16: Eq. (2) with cached spectra
        assert!(LinearPlan::new(&rand_bcm(2, 2, 4, 1), 8).fft.is_none());
        assert!(LinearPlan::new(&rand_bcm(2, 2, 16, 2), 32).fft.is_some());
    }

    #[test]
    fn shard_plan_slices_sign_and_keeps_parent_scale() {
        for l in [4usize, 16] {
            let bcm = rand_bcm(4, 2, l, 7);
            let full = LinearPlan::new(&bcm, bcm.n());
            let (sb, sp) = full.shard_of(&bcm, 1, 3);
            assert_eq!((sb.p, sb.q, sb.l), (2, 2, l));
            assert_eq!(sp.fft.is_some(), full.fft.is_some(), "route inherited");
            assert_eq!(sp.sign.scale, full.sign.scale, "global rescale kept");
            let stride = 2 * l;
            assert_eq!(sp.sign.pos.w[..], full.sign.pos.w[stride..3 * stride]);
            assert_eq!(sp.sign.neg.w[..], full.sign.neg.w[stride..3 * stride]);
            // the shard's planned product must equal the matching rows of
            // the full planned product, bit for bit — the farm's reduce
            // step is a plain row concatenation
            let mut r = Rng::new(77);
            let mut xd = vec![0.0f32; bcm.n() * 5];
            r.fill_uniform(&mut xd);
            let x = Tensor::new(&[bcm.n(), 5], xd);
            let want = full.multiply(&bcm, &x, 2);
            let got = sp.multiply(&sb, &x, 2);
            for rr in 0..sb.m() {
                for c in 0..5 {
                    assert_eq!(got.at2(rr, c), want.at2(rr + l, c), "row {rr} col {c}");
                }
            }
        }
    }

    #[test]
    fn planned_multiply_matches_reference_bitwise() {
        for (l, seed) in [(4usize, 3u64), (16, 4)] {
            let bcm = rand_bcm(3, 2, l, seed);
            let plan = LinearPlan::new(&bcm, bcm.n());
            let mut r = Rng::new(seed + 10);
            let mut xd = vec![0.0f32; bcm.n() * 6];
            r.fill_uniform(&mut xd);
            let x = Tensor::new(&[bcm.n(), 6], xd);
            let want = plan.multiply_reference(&bcm, &x);
            for threads in [1usize, 4] {
                assert_eq!(
                    plan.multiply(&bcm, &x, threads).data,
                    want.data,
                    "l={l} threads={threads}"
                );
            }
        }
    }
}
