//! StrC-ONN inference engine — the L3 twin of `python/compile/model.py`.
//!
//! Loads a trained model (JSON manifest + CPT1 weight bundle, both written
//! by `python -m compile.train`) and executes it through either:
//!
//! * [`Backend::Digital`]      — pure-rust fp32 tensor math (baseline);
//! * [`Backend::PhotonicSim`]  — every conv/FC layer streamed through the
//!   CirPTC [`crate::simulator::ChipSim`] as sign-split BCM tiles with
//!   quantization, crosstalk, dark current and noise (the paper's
//!   lookup-mode on-chip inference);
//! * the XLA runtime path (whole-network AOT artifact) lives in
//!   [`crate::coordinator`] — it needs no layer graph.

pub mod engine;
pub mod manifest;
pub mod plan;

pub use engine::{Backend, Engine, MidBatch, PreBatch};
pub use manifest::{LayerKind, LayerSpec, Manifest};
