//! The individual validation passes behind
//! [`super::validate_artifacts`].  Each pass appends attributed
//! [`Diagnostic`]s and never early-exits, so one run reports every
//! violation at once.  Passes are tolerant where the runtime is tolerant
//! (a first layer accepts any input, unknown bundle names that don't look
//! like layer tensors are ignored) and strict exactly where the engine
//! would otherwise panic or serve garbage.

use crate::circulant::{fft, Bcm};
use crate::data::bundle::Entry;
use crate::data::Bundle;
use crate::farm::partition::{circ_grids, tile_demand, PartitionPlan};
use crate::onn::manifest::{LayerKind, LayerSpec};
use crate::onn::Manifest;
use crate::simulator::ChipDescription;

use super::Diagnostic;

fn diag(
    pass: &'static str,
    layer: Option<usize>,
    field: impl Into<String>,
    expected: impl Into<String>,
    found: impl Into<String>,
    message: impl Into<String>,
) -> Diagnostic {
    Diagnostic {
        pass,
        layer,
        field: field.into(),
        expected: expected.into(),
        found: found.into(),
        message: message.into(),
    }
}

fn is_linear(spec: &LayerSpec) -> bool {
    matches!(spec.kind, LayerKind::Conv | LayerKind::Fc)
}

fn is_circ(spec: &LayerSpec) -> bool {
    is_linear(spec) && spec.arch == "circ"
}

/// What a layer's activation looks like while walking the graph.
enum Sig {
    /// nothing known yet (model input, or downstream of a broken layer)
    Unknown,
    /// image activation with this many channels
    Image(usize),
    /// flattened image features; channel count still known
    Flat(usize),
    /// flat feature vector of exactly this width (after an fc)
    Width(usize),
}

/// Layer-graph shape propagation: walk the stack once, tracking what each
/// layer hands to the next, and flag every place the declared `cin`
/// cannot match what actually arrives.  Channel-based (spatial size
/// depends on the served image, which the engine accepts dynamically), so
/// a violation here is a contradiction *within* the manifest — it cannot
/// be fixed by feeding a different input.
pub fn check_graph(manifest: &Manifest, out: &mut Vec<Diagnostic>) {
    let mut sig = Sig::Unknown;
    for (i, spec) in manifest.layers.iter().enumerate() {
        match spec.kind {
            LayerKind::Conv => {
                if spec.cout == 0 || spec.n_in() == 0 {
                    out.push(diag(
                        "graph",
                        Some(i),
                        "cout/cin/k",
                        "positive dimensions",
                        format!("cin={} cout={} k={}", spec.cin, spec.cout, spec.k),
                        "conv layer with a zero-sized weight grid",
                    ));
                }
                match sig {
                    Sig::Image(c) if c != spec.cin => out.push(diag(
                        "graph",
                        Some(i),
                        "cin",
                        format!("{c} (previous layer's output channels)"),
                        format!("{}", spec.cin),
                        "conv input channels contradict the layer above",
                    )),
                    Sig::Flat(_) | Sig::Width(_) => out.push(diag(
                        "graph",
                        Some(i),
                        "kind",
                        "image activation",
                        "flattened activation",
                        "conv cannot follow flatten/fc",
                    )),
                    _ => {}
                }
                sig = Sig::Image(spec.cout);
            }
            LayerKind::Fc => {
                if spec.cout == 0 || spec.cin == 0 {
                    out.push(diag(
                        "graph",
                        Some(i),
                        "cin/cout",
                        "positive dimensions",
                        format!("cin={} cout={}", spec.cin, spec.cout),
                        "fc layer with a zero-sized weight grid",
                    ));
                }
                match sig {
                    Sig::Width(n) if n != spec.cin => out.push(diag(
                        "graph",
                        Some(i),
                        "cin",
                        format!("{n} (previous fc's output width)"),
                        format!("{}", spec.cin),
                        "fc input width contradicts the layer above",
                    )),
                    // after an image/flatten, the flat width is
                    // channels·H·W for some spatial size — cin must at
                    // least be a multiple of the channel count
                    Sig::Image(c) | Sig::Flat(c) if spec.cin % c.max(1) != 0 => {
                        out.push(diag(
                            "graph",
                            Some(i),
                            "cin",
                            format!("a multiple of {c} (upstream channels)"),
                            format!("{}", spec.cin),
                            "fc width cannot be channels·H·W for any H·W",
                        ))
                    }
                    _ => {}
                }
                sig = Sig::Width(spec.cout);
            }
            LayerKind::Bn => {
                match sig {
                    Sig::Image(c) if c != spec.cin => out.push(diag(
                        "graph",
                        Some(i),
                        "cin",
                        format!("{c} (channels being normalized)"),
                        format!("{}", spec.cin),
                        "bn channel count contradicts the layer above",
                    )),
                    Sig::Width(n) if n != spec.cin => out.push(diag(
                        "graph",
                        Some(i),
                        "cin",
                        format!("{n} (features being normalized)"),
                        format!("{}", spec.cin),
                        "bn feature count contradicts the fc above",
                    )),
                    _ => {}
                }
                if matches!(sig, Sig::Unknown) {
                    sig = Sig::Image(spec.cin);
                }
            }
            LayerKind::Relu => {}
            LayerKind::Pool => {
                if matches!(sig, Sig::Flat(_) | Sig::Width(_)) {
                    out.push(diag(
                        "graph",
                        Some(i),
                        "kind",
                        "image activation",
                        "flattened activation",
                        "pool cannot follow flatten/fc",
                    ));
                }
            }
            LayerKind::Flatten => {
                sig = match sig {
                    Sig::Image(c) => Sig::Flat(c),
                    Sig::Flat(_) | Sig::Width(_) => {
                        out.push(diag(
                            "graph",
                            Some(i),
                            "kind",
                            "image activation",
                            "already-flattened activation",
                            "flatten applied twice",
                        ));
                        Sig::Unknown
                    }
                    Sig::Unknown => Sig::Unknown,
                };
            }
        }
    }
}

fn entry_f32<'a>(
    pass: &'static str,
    layer: usize,
    bundle: &'a Bundle,
    name: &str,
    out: &mut Vec<Diagnostic>,
) -> Option<&'a Entry> {
    match bundle.tensors.get(name) {
        Some(e) => {
            if e.as_f32().is_err() {
                out.push(diag(
                    pass,
                    Some(layer),
                    name,
                    "f32 tensor",
                    "i32 tensor",
                    "wrong dtype for a weight tensor",
                ));
                None
            } else {
                Some(e)
            }
        }
        None => {
            out.push(diag(
                pass,
                Some(layer),
                name,
                "tensor present in bundle",
                "missing",
                "the layer's weights are absent",
            ));
            None
        }
    }
}

fn check_finite(
    pass: &'static str,
    layer: usize,
    name: &str,
    data: &[f32],
    out: &mut Vec<Diagnostic>,
) -> bool {
    let bad = data.iter().filter(|v| !v.is_finite()).count();
    if bad > 0 {
        out.push(diag(
            pass,
            Some(layer),
            name,
            "all values finite",
            format!("{bad} non-finite of {}", data.len()),
            "NaN/Inf parameters poison every downstream activation",
        ));
    }
    bad == 0
}

/// Tensor presence, shape and finiteness for every stateful layer.
pub fn check_tensors(manifest: &Manifest, bundle: &Bundle, out: &mut Vec<Diagnostic>) {
    for (i, spec) in manifest.layers.iter().enumerate() {
        let name = format!("layer{i}");
        match spec.kind {
            LayerKind::Conv | LayerKind::Fc => {
                let wname = format!("{name}.w");
                if let Some(w) = entry_f32("tensors", i, bundle, &wname, out) {
                    if spec.arch == "circ" {
                        let (p, q) = spec.bcm_dims();
                        if w.shape() != [p, q, spec.l] {
                            out.push(diag(
                                "tensors",
                                Some(i),
                                &wname,
                                format!("shape [{p}, {q}, {}]", spec.l),
                                format!("shape {:?}", w.shape()),
                                "compressed BCM grid disagrees with the \
                                 manifest's (cout, n_in, l)",
                            ));
                        }
                    } else {
                        let want = spec.cout * spec.n_in();
                        let got: usize = w.shape().iter().product();
                        if got != want {
                            out.push(diag(
                                "tensors",
                                Some(i),
                                &wname,
                                format!("{want} elements (cout × n_in)"),
                                format!("{got} elements"),
                                "dense weight size disagrees with the manifest",
                            ));
                        }
                    }
                    if let Ok(data) = w.as_f32() {
                        check_finite("tensors", i, &wname, data, out);
                    }
                }
                let bname = format!("{name}.b");
                if let Some(b) = entry_f32("tensors", i, bundle, &bname, out) {
                    if let Ok(data) = b.as_f32() {
                        if data.len() != spec.cout {
                            out.push(diag(
                                "tensors",
                                Some(i),
                                &bname,
                                format!("{} values (one per output)", spec.cout),
                                format!("{} values", data.len()),
                                "bias length disagrees with cout",
                            ));
                        }
                        check_finite("tensors", i, &bname, data, out);
                    }
                }
            }
            LayerKind::Bn => {
                for part in ["gamma", "beta", "state.mean", "state.var"] {
                    let tname = format!("{name}.{part}");
                    if let Some(t) = entry_f32("tensors", i, bundle, &tname, out) {
                        if let Ok(data) = t.as_f32() {
                            if data.len() != spec.cin {
                                out.push(diag(
                                    "tensors",
                                    Some(i),
                                    &tname,
                                    format!("{} values (one per channel)", spec.cin),
                                    format!("{} values", data.len()),
                                    "bn statistics length disagrees with cin",
                                ));
                            }
                        }
                    }
                }
            }
            _ => {}
        }
    }
}

/// Block-size divisibility: for every circ layer, the block order must
/// divide the padded operand width the stored tensor implies (`l | n_pad`
/// — Eq. (1)'s partitioning is undefined otherwise).
pub fn check_blocks(manifest: &Manifest, bundle: &Bundle, out: &mut Vec<Diagnostic>) {
    for (i, spec) in manifest.layers.iter().enumerate() {
        if !is_circ(spec) {
            continue;
        }
        if spec.l == 0 {
            out.push(diag(
                "blocks",
                Some(i),
                "l",
                "block order ≥ 1",
                "0",
                "a zero block order cannot partition anything",
            ));
            continue;
        }
        if let Some(w) = bundle.tensors.get(&format!("layer{i}.w")) {
            if w.shape().len() == 3 {
                let n_pad = w.shape()[1] * w.shape()[2];
                if n_pad % spec.l != 0 {
                    out.push(diag(
                        "blocks",
                        Some(i),
                        format!("layer{i}.w"),
                        format!("padded width divisible by l={}", spec.l),
                        format!("n_pad={n_pad}"),
                        "the stored grid cannot be partitioned into \
                         l-sized circulant blocks",
                    ));
                }
            }
        }
    }
}

/// BN statistics sanity: finite values, non-negative variances.
pub fn check_bn_stats(manifest: &Manifest, bundle: &Bundle, out: &mut Vec<Diagnostic>) {
    for (i, spec) in manifest.layers.iter().enumerate() {
        if spec.kind != LayerKind::Bn {
            continue;
        }
        for part in ["gamma", "beta", "state.mean", "state.var"] {
            let tname = format!("layer{i}.{part}");
            let Some(Ok(data)) = bundle.tensors.get(&tname).map(Entry::as_f32)
            else {
                continue; // presence/dtype handled by the tensors pass
            };
            if !check_finite("bn", i, &tname, data, out) {
                continue;
            }
            if part == "state.var" {
                let neg = data.iter().filter(|v| **v < 0.0).count();
                if neg > 0 {
                    out.push(diag(
                        "bn",
                        Some(i),
                        &tname,
                        "variances ≥ 0",
                        format!("{neg} negative"),
                        "a negative variance makes the normalizer NaN",
                    ));
                }
            }
        }
    }
}

/// Quantizer sanity: every linear layer's activation scale must be a
/// finite, positive number (the fixed-point grid divides by it).
pub fn check_quantizers(manifest: &Manifest, out: &mut Vec<Diagnostic>) {
    for (i, spec) in manifest.layers.iter().enumerate() {
        if !is_linear(spec) {
            continue;
        }
        if !(spec.act_scale.is_finite() && spec.act_scale > 0.0) {
            out.push(diag(
                "quantizer",
                Some(i),
                "act_scale",
                "finite and > 0",
                format!("{}", spec.act_scale),
                "the activation quantizer grid would be degenerate",
            ));
        }
    }
}

/// Conjugate-symmetry check over an interleaved spectra buffer
/// (`[re; l][im; l]` per block, [`fft::WeightSpectra`] layout).  The
/// spectrum of a real first column must satisfy `X[k] = conj(X[l-k])` —
/// a violation means the cached spectra were not produced from the
/// weights they claim to summarize.
pub fn check_spectra(
    layer: Option<usize>,
    l: usize,
    n_blocks: usize,
    data: &[f32],
) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let l2 = 2 * l;
    if data.len() != n_blocks * l2 {
        out.push(diag(
            "spectra",
            layer,
            "spectra",
            format!("{} values ({n_blocks} blocks × 2l)", n_blocks * l2),
            format!("{} values", data.len()),
            "spectra buffer length disagrees with the block grid",
        ));
        return out;
    }
    for blk in 0..n_blocks {
        let (re, im) = data[blk * l2..(blk + 1) * l2].split_at(l);
        let scale = re
            .iter()
            .chain(im.iter())
            .fold(1.0f32, |m, v| m.max(v.abs()));
        if !scale.is_finite() {
            out.push(diag(
                "spectra",
                layer,
                format!("block {blk}"),
                "finite spectrum",
                "non-finite values",
                "spectra computed from non-finite weights",
            ));
            continue;
        }
        let tol = 1e-3 * scale;
        let mut broken = im[0].abs() > tol;
        for k in 1..l {
            if (re[k] - re[l - k]).abs() > tol || (im[k] + im[l - k]).abs() > tol {
                broken = true;
            }
        }
        if broken {
            out.push(diag(
                "spectra",
                layer,
                format!("block {blk}"),
                "conjugate-symmetric spectrum (real first column)",
                "asymmetric spectrum",
                "cached spectrum does not match any real weight block",
            ));
        }
    }
    out
}

/// Weight-spectra consistency for every circ layer: the spectra the
/// planned FFT path would cache must have the length the block grid
/// implies, and (for layers past the FFT crossover) must come out
/// conjugate-symmetric when rebuilt from the stored weights.
pub fn check_weight_spectra(
    manifest: &Manifest,
    bundle: &Bundle,
    out: &mut Vec<Diagnostic>,
) {
    for (i, spec) in manifest.layers.iter().enumerate() {
        if !is_circ(spec) || spec.l == 0 {
            continue;
        }
        let Some(w) = bundle.tensors.get(&format!("layer{i}.w")) else {
            continue; // missing handled by the tensors pass
        };
        let sh = w.shape();
        let Ok(data) = w.as_f32() else {
            continue; // dtype handled by the tensors pass
        };
        if sh.len() != 3 {
            continue; // shape handled by the tensors pass
        }
        let (p, q) = spec.bcm_dims();
        let expected = p * q * 2 * spec.l;
        let implied = sh[0] * sh[1] * 2 * sh[2];
        if implied != expected {
            out.push(diag(
                "spectra",
                Some(i),
                format!("layer{i}.w"),
                format!("spectra of {expected} values ({p}×{q} blocks × 2·l)"),
                format!("spectra of {implied} values"),
                "stored grid would cache spectra of the wrong length",
            ));
            continue;
        }
        // full rebuild + symmetry check only where the planned path would
        // actually cache spectra (past the FFT crossover) and the data is
        // clean enough to FFT
        if sh == [p, q, spec.l]
            && fft::use_fft_path(spec.l)
            && data.iter().all(|v| v.is_finite())
        {
            let bcm = Bcm::new(p, q, spec.l, data.to_vec());
            let plan = fft::plan_for(spec.l);
            let spectra = fft::WeightSpectra::new(&bcm, &plan);
            out.extend(check_spectra(
                Some(i),
                spectra.block_order(),
                spectra.n_blocks(),
                spectra.raw(),
            ));
        }
    }
}

/// Chip capability: the description must be internally consistent, and
/// every circ layer's block order must match the MRR bank the chip
/// actually has.
pub fn check_chip(
    manifest: &Manifest,
    chip: &ChipDescription,
    out: &mut Vec<Diagnostic>,
) {
    if chip.gamma.len() != chip.l * chip.l {
        out.push(diag(
            "chip",
            None,
            "gamma_true",
            format!("{}×{} crosstalk operator", chip.l, chip.l),
            format!("{} values", chip.gamma.len()),
            "crosstalk operator size disagrees with the chip's l",
        ));
    }
    if chip.resp.len() != chip.l {
        out.push(diag(
            "chip",
            None,
            "resp",
            format!("{} responsivities (one per wavelength)", chip.l),
            format!("{} values", chip.resp.len()),
            "responsivity vector disagrees with the chip's l",
        ));
    }
    let all_finite = chip
        .gamma
        .iter()
        .chain(chip.resp.iter())
        .all(|v| v.is_finite())
        && chip.dark.is_finite();
    if !all_finite {
        out.push(diag(
            "chip",
            None,
            "gamma_true/resp/dark",
            "finite values",
            "non-finite values",
            "a non-finite chip parameter poisons every pass",
        ));
    }
    for (fname, v) in [("sigma_rel", chip.sigma_rel), ("sigma_abs", chip.sigma_abs)] {
        if !(v.is_finite() && v >= 0.0) {
            out.push(diag(
                "chip",
                None,
                fname,
                "finite and ≥ 0",
                format!("{v}"),
                "noise amplitudes cannot be negative",
            ));
        }
    }
    for (fname, b) in [("w_bits", chip.w_bits), ("x_bits", chip.x_bits)] {
        if b > 32 {
            out.push(diag(
                "chip",
                None,
                fname,
                "0 (disabled) or 1..=32",
                format!("{b}"),
                "DAC resolution beyond 32 bits is not representable",
            ));
        }
    }
    for (i, spec) in manifest.layers.iter().enumerate() {
        if is_circ(spec) && spec.l != chip.l {
            out.push(diag(
                "chip",
                Some(i),
                "l",
                format!("{} (the chip's MRR bank size)", chip.l),
                format!("{}", spec.l),
                "block order does not fit the chip's wavelength bank",
            ));
        }
    }
}

/// Partition feasibility against the chip's declared MRR bank
/// ([`ChipDescription::mrr_capacity`], `0` = unlimited → no-op).  The
/// farm planner's unit of assignment is a whole block-row of `Q`
/// resident tiles, so a layer whose `Q` exceeds the bank cannot be
/// served by *any* farm width; otherwise the model must admit some
/// width whose per-chip load fits ([`PartitionPlan::required_chips`]).
/// A deeper structural check of a concrete plan (dangling block-rows,
/// gaps, overlaps) lives in [`PartitionPlan::validate`] and runs when a
/// [`crate::farm::PartitionedEngine`] is built.
pub fn check_partition(
    manifest: &Manifest,
    chip: &ChipDescription,
    out: &mut Vec<Diagnostic>,
) {
    let cap = chip.mrr_capacity;
    if cap == 0 {
        return;
    }
    let mut indivisible = false;
    for g in circ_grids(manifest) {
        if g.q > cap {
            indivisible = true;
            out.push(diag(
                "partition",
                Some(g.layer),
                "mrr_capacity",
                format!("≥ {} tiles (one block-row is the unit of assignment)", g.q),
                format!("{cap}"),
                "a single block-row exceeds the chip's MRR bank; \
                 no farm width can serve this layer",
            ));
        }
    }
    if !indivisible && PartitionPlan::required_chips(manifest, cap).is_none() {
        out.push(diag(
            "partition",
            None,
            "mrr_capacity",
            format!("a farm width whose per-chip load fits {cap} tiles"),
            format!("{} tiles of demand, no width fits", tile_demand(manifest)),
            "no contiguous block-row partition fits the declared MRR bank",
        ));
    }
}

/// Artifact coverage: every `layer{N}.…` tensor in the bundle must refer
/// to a real layer and a field that layer actually has.  Catches dangling
/// references (a renamed/reordered stack leaving orphaned weights) that
/// would otherwise be silently ignored at load time.
pub fn check_artifact_coverage(
    manifest: &Manifest,
    bundle: &Bundle,
    out: &mut Vec<Diagnostic>,
) {
    for name in bundle.tensors.keys() {
        let Some(rest) = name.strip_prefix("layer") else {
            continue; // non-layer tensors (datasets, calibration) are fine
        };
        let Some(dot) = rest.find('.') else {
            continue;
        };
        let Ok(idx) = rest[..dot].parse::<usize>() else {
            continue;
        };
        let field = &rest[dot + 1..];
        let Some(spec) = manifest.layers.get(idx) else {
            out.push(diag(
                "artifacts",
                None,
                name.clone(),
                format!("layer index < {}", manifest.layers.len()),
                format!("layer{idx}"),
                "tensor refers to a layer the manifest does not have",
            ));
            continue;
        };
        let valid: &[&str] = match spec.kind {
            LayerKind::Conv | LayerKind::Fc => &["w", "b"],
            LayerKind::Bn => &["gamma", "beta", "state.mean", "state.var"],
            _ => &[],
        };
        if !valid.contains(&field) {
            out.push(diag(
                "artifacts",
                Some(idx),
                name.clone(),
                if valid.is_empty() {
                    "no tensors (stateless layer)".to_string()
                } else {
                    format!("one of {valid:?}")
                },
                format!("'{field}'"),
                "tensor does not belong to this layer kind",
            ));
        }
    }
}
