//! Static artifact/plan verification (DESIGN.md §verify).
//!
//! A pass pipeline that checks a [`Manifest`] + weight [`Bundle`] (and
//! optionally a [`ChipDescription`]) **before** an engine is built from
//! them: layer-graph shape propagation, block-size divisibility, tensor
//! presence/shape/finiteness, BN statistics sanity, quantizer scales,
//! weight-spectra consistency, chip capability, farm-partition
//! feasibility against the chip's declared MRR bank, and dangling
//! artifact references.  Every violation is an attributed, machine-readable
//! [`Diagnostic`] (which layer, which field, expected vs found), so a
//! refused artifact says *what* is wrong instead of failing deep inside
//! layer construction with a shape panic.
//!
//! Wired into [`crate::onn::Engine::from_parts`] and
//! [`crate::train::TrainModel::from_parts`] (hard error by default; the
//! `_unchecked` constructors skip it), and exposed standalone through the
//! `validate` binary for CI and operators.

pub mod passes;

use crate::data::Bundle;
use crate::onn::Manifest;
use crate::simulator::ChipDescription;
use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// One attributed violation found by a validation pass.
#[derive(Clone, Debug, PartialEq)]
pub struct Diagnostic {
    /// which pass fired (`graph`, `tensors`, `blocks`, `bn`, `quantizer`,
    /// `spectra`, `chip`, `partition`, `artifacts`)
    pub pass: &'static str,
    /// the layer the violation is attributed to (`None` for bundle- or
    /// chip-level findings)
    pub layer: Option<usize>,
    /// the manifest field or bundle tensor at fault
    pub field: String,
    /// what a well-formed artifact would contain
    pub expected: String,
    /// what was actually found
    pub found: String,
    /// one-line human explanation
    pub message: String,
}

impl Diagnostic {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("pass", Json::Str(self.pass.to_string())),
            (
                "layer",
                match self.layer {
                    Some(i) => Json::Num(i as f64),
                    None => Json::Null,
                },
            ),
            ("field", Json::Str(self.field.clone())),
            ("expected", Json::Str(self.expected.clone())),
            ("found", Json::Str(self.found.clone())),
            ("message", Json::Str(self.message.clone())),
        ])
    }

    /// One-line rendering for logs / the `validate` CLI.
    pub fn render(&self) -> String {
        let at = match self.layer {
            Some(i) => format!("layer {i} "),
            None => String::new(),
        };
        format!(
            "{at}[{}] {}: expected {}, found {} — {}",
            self.pass, self.field, self.expected, self.found, self.message
        )
    }
}

/// The outcome of a validation run: every diagnostic from every pass
/// (passes never early-exit, so one run reports all violations at once).
#[derive(Debug, Default)]
pub struct Report {
    pub diagnostics: Vec<Diagnostic>,
}

impl Report {
    pub fn is_ok(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// Machine-readable dump: `{"ok": bool, "diagnostics": [...]}` with
    /// stable key order.
    pub fn json_dump(&self) -> String {
        Json::obj(vec![
            ("ok", Json::Bool(self.is_ok())),
            (
                "diagnostics",
                Json::Arr(self.diagnostics.iter().map(Diagnostic::to_json).collect()),
            ),
        ])
        .dump()
    }

    /// Collapse into a crate [`Result`]: the error message carries the
    /// per-line renderings plus the JSON dump, so a refused
    /// `Engine::from_parts` is diagnosable from the error alone.
    pub fn into_result(self, context: &str) -> Result<()> {
        if self.is_ok() {
            return Ok(());
        }
        let lines: Vec<String> =
            self.diagnostics.iter().map(Diagnostic::render).collect();
        Err(Error::msg(format!(
            "{context}: {} validation error(s):\n  {}\n{}",
            self.diagnostics.len(),
            lines.join("\n  "),
            self.json_dump()
        )))
    }
}

/// Run the full pass pipeline over a manifest + bundle (+ optional chip).
///
/// Returns every violation found; an empty report means the artifacts are
/// structurally sound and an engine built from them cannot hit a shape,
/// divisibility or non-finite-parameter failure at load or serve time.
pub fn validate_artifacts(
    manifest: &Manifest,
    bundle: &Bundle,
    chip: Option<&ChipDescription>,
) -> Report {
    let mut out = Vec::new();
    passes::check_graph(manifest, &mut out);
    passes::check_tensors(manifest, bundle, &mut out);
    passes::check_blocks(manifest, bundle, &mut out);
    passes::check_bn_stats(manifest, bundle, &mut out);
    passes::check_quantizers(manifest, &mut out);
    passes::check_weight_spectra(manifest, bundle, &mut out);
    if let Some(c) = chip {
        passes::check_chip(manifest, c, &mut out);
        passes::check_partition(manifest, c, &mut out);
    }
    passes::check_artifact_coverage(manifest, bundle, &mut out);
    Report { diagnostics: out }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagnostic_renders_and_dumps() {
        let d = Diagnostic {
            pass: "graph",
            layer: Some(3),
            field: "cin".into(),
            expected: "8".into(),
            found: "4".into(),
            message: "channel mismatch".into(),
        };
        let r = d.render();
        assert!(r.contains("layer 3"));
        assert!(r.contains("[graph]"));
        assert!(r.contains("expected 8, found 4"));
        let rep = Report { diagnostics: vec![d] };
        assert!(!rep.is_ok());
        let dump = rep.json_dump();
        assert!(dump.contains("\"ok\":false"));
        assert!(dump.contains("\"layer\":3"));
        let err = rep.into_result("loading model").unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("loading model"));
        assert!(msg.contains("\"pass\":\"graph\""), "json dump embedded: {msg}");
    }

    #[test]
    fn empty_report_is_ok() {
        let rep = Report::default();
        assert!(rep.is_ok());
        assert!(rep.json_dump().contains("\"ok\":true"));
        assert!(rep.into_result("x").is_ok());
    }
}
