//! Runtime integration: AOT HLO artifacts load, compile and execute on the
//! PJRT CPU client, and their numerics match the pure-rust implementations
//! (the L1 Pallas kernel ≡ rust BCM algebra contract).
//!
//! Compiled only with `--features pjrt` (and runnable only with a real
//! xla binding patched over the vendored stub — see README §PJRT).

#![cfg(feature = "pjrt")]

use std::path::PathBuf;

use cirptc::circulant::Bcm;
use cirptc::runtime::Runtime;
use cirptc::simulator::{ChipDescription, ChipSim};
use cirptc::tensor::Tensor;
use cirptc::util::rng::Rng;

fn artifacts() -> Option<PathBuf> {
    // the crate manifest lives in rust/; artifacts/ sits at the workspace
    // root next to benches/ and examples/
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn rand_tensor(shape: &[usize], seed: u64) -> Tensor {
    let mut r = Rng::new(seed);
    let mut d = vec![0.0f32; shape.iter().product()];
    r.fill_uniform(&mut d);
    Tensor::new(shape, d)
}

#[test]
fn pallas_bcm_artifact_matches_rust_bcm() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let mut rt = Runtime::new(&dir).unwrap();
    for (p, q, l, b, name) in [
        (4usize, 4usize, 4usize, 8usize, "bcm_16x16_b8"),
        (12, 12, 4, 16, "bcm_48x48_b16"),
        (16, 16, 4, 16, "bcm_64x64_b16"),
    ] {
        let exe = rt.load(name).unwrap();
        let w = rand_tensor(&[p, q, l], 10 + p as u64);
        let x = rand_tensor(&[q * l, b], 20 + p as u64);
        let y_xla = exe.run(&[&w, &x]).unwrap();
        let bcm = Bcm::new(p, q, l, w.data.clone());
        let y_rust = bcm.matmul(&x);
        assert_eq!(y_xla.len(), y_rust.numel());
        let max_diff = y_xla
            .iter()
            .zip(&y_rust.data)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
        assert!(max_diff < 1e-4, "{name}: pallas-vs-rust max |Δ| = {max_diff}");
    }
}

#[test]
fn crossbar_artifact_matches_simulator() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let mut rt = Runtime::new(&dir).unwrap();
    let chip = ChipDescription::load(&dir.join("chip.json")).unwrap();
    let (p, q, l, b) = (12usize, 12usize, 4usize, 16usize);
    let exe = rt.load("crossbar_48x48_b16").unwrap();
    let w = rand_tensor(&[p, q, l], 31);
    let x = rand_tensor(&[q * l, b], 32);
    let y_xla = exe.run(&[&w, &x]).unwrap();
    // The AOT crossbar graph uses the *nominal* Γ (no per-instance fab
    // perturbation or resp tilt — those are serving-time, sim-side); mirror
    // that config here.
    let mut desc = ChipDescription::ideal(l);
    desc.w_bits = chip.w_bits;
    desc.x_bits = chip.x_bits;
    desc.dark = chip.dark;
    // nominal Γ from eps (reconstruct the python crosstalk_matrix(4, eps))
    let eps = 0.02f64;
    for i in 0..l {
        let mut row = [0.0f64; 4];
        let mut sum = 0.0;
        for (j, r) in row.iter_mut().enumerate() {
            *r = eps.powi((i as i32 - j as i32).abs());
            sum += *r;
        }
        for j in 0..l {
            desc.gamma[i * l + j] = (row[j] / sum) as f32;
        }
    }
    let mut sim = ChipSim::deterministic(desc);
    let y_sim = sim.forward(&Bcm::new(p, q, l, w.data.clone()), &x);
    let max_diff = y_xla
        .iter()
        .zip(&y_sim.data)
        .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
    assert!(
        max_diff < 2e-3,
        "crossbar artifact vs rust sim max |Δ| = {max_diff}"
    );
}

#[test]
fn gemm_artifact_matches_dense_matmul() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let mut rt = Runtime::new(&dir).unwrap();
    let exe = rt.load("gemm_16x16_b8").unwrap();
    let w = rand_tensor(&[16, 16], 41);
    let x = rand_tensor(&[16, 8], 42);
    let y = exe.run(&[&w, &x]).unwrap();
    let want = w.matmul(&x);
    let max_diff = y
        .iter()
        .zip(&want.data)
        .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
    assert!(max_diff < 1e-4);
}

#[test]
fn model_artifact_runs_batch() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let mut rt = Runtime::new(&dir).unwrap();
    let exe = rt.load("model_synth_cxr").unwrap();
    let x = rand_tensor(&[8, 1, 64, 64], 50);
    let y = exe.run(&[&x]).unwrap();
    assert_eq!(y.len(), 8 * 3);
    assert!(y.iter().all(|v| v.is_finite()));
}

/// XLA digital model artifact ≡ rust engine digital path on the same
/// weights — the strongest end-to-end L2↔L3 consistency check.
#[test]
fn model_artifact_matches_rust_engine() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let manifest = dir.join("models/synth_cxr.json");
    if !manifest.exists() {
        eprintln!("skipping: train.py not run");
        return;
    }
    // model_synth_cxr bakes the *digitally-trained* weights (aot.py);
    // compare against the engine loading the same bundle
    let bundle = dir.join("models/synth_cxr_digital.cpt");
    let bundle = if bundle.exists() {
        bundle
    } else {
        dir.join("models/synth_cxr_dpe.cpt")
    };
    let engine = cirptc::onn::Engine::load(&manifest, &bundle).unwrap();
    let mut rt = Runtime::new(&dir).unwrap();
    let exe = rt.load("model_synth_cxr").unwrap();

    let img = rand_tensor(&[1, 64, 64], 60);
    let mut batch = vec![0.0f32; 8 * 64 * 64];
    batch[..64 * 64].copy_from_slice(&img.data);
    let y_xla = exe.run(&[&Tensor::new(&[8, 1, 64, 64], batch)]).unwrap();
    let y_rust = engine
        .forward(&img, &mut cirptc::onn::Backend::Digital)
        .unwrap();
    for (i, (a, b)) in y_xla[..3].iter().zip(&y_rust).enumerate() {
        assert!(
            (a - b).abs() < 2e-2,
            "logit {i}: xla {a} vs rust {b}"
        );
    }
}
