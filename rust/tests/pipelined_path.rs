//! Pipelined-vs-sequential bit-identity: the contract of the stage
//! pipeline (DESIGN.md §pipeline).
//!
//! The pipelined coordinator (pre / chip / post lanes, speculative
//! operand pre-encode, bounded inter-stage buffers) must produce
//! **exactly** the bytes of `Engine::forward_batch` run sequentially —
//! across random engine shapes and batch sizes, on both backends, with
//! chip noise, through drift episodes that retire pre-encoded operands
//! mid-stream, and across an `EngineSlot` hot swap.  Overlap is a
//! throughput lever only; it must never be observable in the numbers.

use std::sync::Arc;

use cirptc::coordinator::{
    BatcherConfig, Coordinator, EngineSource, Staged, StagedFactory,
};
use cirptc::data::Bundle;
use cirptc::drift::{DriftConfig, DriftModel, EngineSlot};
use cirptc::onn::{Backend, Engine, Manifest};
use cirptc::simulator::{ChipDescription, ChipSim};
use cirptc::tensor::Tensor;
use cirptc::util::propcheck;
use cirptc::util::rng::Rng;

/// A mildly non-ideal chip of block order `l` (same as planned_path.rs).
fn chip(l: usize) -> ChipDescription {
    let mut d = ChipDescription::ideal(l);
    for i in 0..l {
        for j in 0..l {
            if i != j {
                d.gamma[i * l + j] = 0.02 / (1.0 + (i as f32 - j as f32).abs());
            }
        }
        d.resp[i] = 1.0 - 0.02 * i as f32;
    }
    d.w_bits = 6;
    d.x_bits = 4;
    d.dark = 0.01;
    d
}

/// In-memory circ engine: conv(1→cout, k=3) → relu → flatten → fc → 3
/// classes, on 8×8 inputs, all layers at block order `l`.
fn build_engine(l: usize, cout: usize, seed: u64) -> Engine {
    let n_fc = cout * 64;
    let manifest = Manifest::parse(&format!(
        r#"{{
          "dataset": "pipelined_prop", "classes": 3,
          "layers": [
            {{"kind": "conv", "cin": 1, "cout": {cout}, "k": 3, "pool": 2,
             "arch": "circ", "l": {l}, "act_scale": 4.0}},
            {{"kind": "relu", "cin": 0, "cout": 0, "k": 3, "pool": 2,
             "arch": "circ", "l": {l}, "act_scale": 4.0}},
            {{"kind": "flatten", "cin": 0, "cout": 0, "k": 3, "pool": 2,
             "arch": "circ", "l": {l}, "act_scale": 4.0}},
            {{"kind": "fc", "cin": {n_fc}, "cout": 3, "k": 3, "pool": 2,
             "arch": "circ", "l": {l}, "act_scale": 4.0}}
          ]}}"#
    ))
    .unwrap();
    let mut bundle = Bundle::default();
    let mut rng = Rng::new(seed);
    let specs = manifest.layers.clone();
    for (i, spec) in specs.iter().enumerate() {
        if !matches!(spec.kind.as_str(), "conv" | "fc") {
            continue;
        }
        let (p, q) = spec.bcm_dims();
        let mut w = vec![0.0f32; p * q * spec.l];
        rng.fill_uniform(&mut w);
        for v in w.iter_mut() {
            *v = (*v - 0.5) * 0.4;
        }
        bundle.insert_f32(&format!("layer{i}.w"), &[p, q, spec.l], w);
        let mut bias = vec![0.0f32; spec.cout];
        rng.fill_uniform(&mut bias);
        bundle.insert_f32(&format!("layer{i}.b"), &[spec.cout], bias);
    }
    Engine::from_parts(manifest, &bundle).unwrap()
}

fn images(n: usize, seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut d = vec![0.0f32; 64];
            rng.fill_uniform(&mut d);
            Tensor::new(&[1, 8, 8], d)
        })
        .collect()
}

fn accel_drift(seed: u64) -> DriftConfig {
    DriftConfig {
        seed,
        passes_per_tick: 3,
        gamma_walk: 2e-3,
        resp_tilt: 5e-3,
        dark_creep: 1e-4,
        max_ticks: 0,
    }
}

/// Serve `imgs` through a single pipelined worker with a deterministic
/// batch partition: every image is submitted up front from one thread
/// (FIFO intake order), `max_batch = bsz` and a generous deadline, so
/// the batcher's greedy drain forms exactly `imgs.len()/bsz` batches of
/// `bsz` in order — the same groups the sequential oracle runs.
/// Returns per-request logits in submit order.
fn serve_pipelined(
    engine: Arc<Engine>,
    backend: Backend,
    imgs: &[Tensor],
    bsz: usize,
) -> Vec<Vec<f32>> {
    assert_eq!(imgs.len() % bsz, 0, "use full batches for determinism");
    let c = Coordinator::start_pipelined(
        vec![Box::new(move || {
            Staged::new(EngineSource::Fixed(engine), backend)
        }) as StagedFactory],
        BatcherConfig { max_batch: bsz, max_wait_us: 2_000_000, queue_cap: 0 },
    );
    let admissions: Vec<_> =
        imgs.iter().map(|im| c.submit(im.clone())).collect();
    let out: Vec<Vec<f32>> = admissions
        .into_iter()
        .map(|a| a.wait().unwrap().logits)
        .collect();
    assert_eq!(c.metrics.errors.get(), 0, "no batch may fail");
    assert_eq!(c.metrics.completed.get(), imgs.len());
    assert_eq!(c.metrics.queue_depth.get(), 0);
    out
}

/// The sequential oracle: the same engine, the same batch groups, one
/// `forward_batch` at a time on a twin backend.
fn serve_sequential(
    engine: &Engine,
    backend: &mut Backend,
    imgs: &[Tensor],
    bsz: usize,
) -> Vec<Vec<f32>> {
    let mut out = Vec::with_capacity(imgs.len());
    for group in imgs.chunks(bsz) {
        out.extend(engine.forward_batch(group, backend).unwrap());
    }
    out
}

#[test]
fn pipelined_serving_bit_identical_over_random_shapes_and_backends() {
    propcheck::check("pipelined coordinator == sequential", 6, |g| {
        let l = *g.choose(&[2usize, 4]);
        let cout = *g.choose(&[4usize, 8]);
        let bsz = g.usize_in(1, 4);
        let n_batches = g.usize_in(2, 4);
        let seed = g.usize_in(1, 1_000_000) as u64;
        let engine = Arc::new(build_engine(l, cout, seed));
        let imgs = images(bsz * n_batches, seed ^ 0x5EED);

        let got_d = serve_pipelined(
            Arc::clone(&engine),
            Backend::Digital,
            &imgs,
            bsz,
        );
        let want_d =
            serve_sequential(&engine, &mut Backend::Digital, &imgs, bsz);
        cirptc::prop_assert!(
            got_d == want_d,
            "digital diverged: l={l} cout={cout} bsz={bsz}"
        );

        // photonic, including chip *noise*: the speculative pre-encode
        // consumes no RNG, so the pipelined pass stream must draw the
        // exact same noise sequence as the sequential one
        let mut noisy = chip(l);
        noisy.seed = seed ^ 0xA11CE;
        noisy.sigma_rel = 0.01;
        noisy.sigma_abs = 1e-3;
        let got_p = serve_pipelined(
            Arc::clone(&engine),
            Backend::PhotonicSim(ChipSim::new(noisy.clone())),
            &imgs,
            bsz,
        );
        let want_p = serve_sequential(
            &engine,
            &mut Backend::PhotonicSim(ChipSim::new(noisy)),
            &imgs,
            bsz,
        );
        cirptc::prop_assert!(
            got_p == want_p,
            "noisy photonic diverged: l={l} cout={cout} bsz={bsz}"
        );
        Ok(())
    });
}

#[test]
fn pipelined_serving_bit_identical_through_drift_episodes() {
    // drift ticks land on the chip's pass clock; the pipelined chip lane
    // serializes batches FIFO, so the episode must replay exactly — and
    // any tick between a snapshot publish and the next batch's passes
    // retires that batch's pre-encode (the chip lane re-encodes inline,
    // which this equality makes unobservable)
    let engine = Arc::new(build_engine(4, 8, 909));
    let imgs = images(12, 910);
    let drifting = |seed: u64| -> ChipSim {
        let mut sim = ChipSim::deterministic(chip(4));
        sim.set_drift(DriftModel::new(accel_drift(seed)));
        sim
    };
    let got = serve_pipelined(
        Arc::clone(&engine),
        Backend::PhotonicSim(drifting(5)),
        &imgs,
        3,
    );
    let want = serve_sequential(
        &engine,
        &mut Backend::PhotonicSim(drifting(5)),
        &imgs,
        3,
    );
    assert_eq!(got, want, "drift episode must replay bit-identically");
}

#[test]
fn pipelined_hot_swap_bit_identical_and_zero_drop() {
    // engine A serves, then a hot swap lands between batches; the same
    // worker chip keeps running.  The pipelined stream must match the
    // sequential A-then-B stream on a twin chip, with every request
    // answered (the swap drops nothing).
    let a = build_engine(4, 8, 101);
    let b = build_engine(4, 8, 202);
    let slot = Arc::new(EngineSlot::new(a));
    let first = images(6, 7000);
    let second = images(6, 7001);

    let c = Coordinator::start_pipelined(
        vec![{
            let slot = Arc::clone(&slot);
            Box::new(move || {
                Staged::new(
                    EngineSource::Slot(slot),
                    Backend::PhotonicSim(ChipSim::deterministic(chip(4))),
                )
            }) as StagedFactory
        }],
        BatcherConfig { max_batch: 3, max_wait_us: 2_000_000, queue_cap: 0 },
    );
    // first half under A — wait before swapping so the swap is strictly
    // between batches in the pipelined stream too
    let adm: Vec<_> = first.iter().map(|im| c.submit(im.clone())).collect();
    let got_a: Vec<Vec<f32>> =
        adm.into_iter().map(|x| x.wait().unwrap().logits).collect();
    slot.swap(build_engine(4, 8, 202));
    let adm: Vec<_> = second.iter().map(|im| c.submit(im.clone())).collect();
    let got_b: Vec<Vec<f32>> =
        adm.into_iter().map(|x| x.wait().unwrap().logits).collect();
    assert_eq!(c.metrics.completed.get(), 12, "zero dropped requests");
    assert_eq!(c.metrics.errors.get(), 0);

    // sequential oracle: A then B through one twin chip
    let mut twin = Backend::PhotonicSim(ChipSim::deterministic(chip(4)));
    let a_oracle = Arc::new(build_engine(4, 8, 101));
    let want_a = serve_sequential(&a_oracle, &mut twin, &first, 3);
    let want_b = serve_sequential(&b, &mut twin, &second, 3);
    assert_eq!(got_a, want_a, "pre-swap stream must match engine A");
    assert_eq!(got_b, want_b, "post-swap stream must match engine B");
    assert_ne!(got_a[0], got_b[0], "distinct weights must serve distinctly");
}

#[test]
fn pipelined_stage_metrics_account_every_batch_and_request() {
    let engine = Arc::new(build_engine(4, 4, 313));
    let imgs = images(16, 314);
    let c = Coordinator::start_pipelined(
        vec![{
            let engine = Arc::clone(&engine);
            Box::new(move || {
                Staged::new(
                    EngineSource::Fixed(engine),
                    Backend::PhotonicSim(ChipSim::deterministic(chip(4))),
                )
            }) as StagedFactory
        }],
        BatcherConfig { max_batch: 4, max_wait_us: 2_000_000, queue_cap: 0 },
    );
    let responses = c.classify_all(&imgs).unwrap();
    assert_eq!(responses.len(), 16);
    let batches = c.metrics.batches.get() as u64;
    assert_eq!(batches, 4, "16 requests at max_batch=4");
    // each lane records once per batch; wait is per request
    assert_eq!(c.metrics.stage_pre_us.count(), batches);
    assert_eq!(c.metrics.stage_chip_us.count(), batches);
    assert_eq!(c.metrics.stage_post_us.count(), batches);
    assert_eq!(c.metrics.batch_compute_us.count(), batches);
    assert_eq!(c.metrics.batch_wait_us.count(), 16);
    let s = c.metrics.summary();
    assert!(s.contains("pre_p99"), "stage timers must surface: {s}");
}
