//! Model-checked concurrency protocols (DESIGN.md §verify).
//!
//! Compiled only under `RUSTFLAGS="--cfg loom"` (the CI `loom` job and
//! `make loom`); a plain `cargo test` sees an empty crate.  With the cfg
//! set, `cirptc::util::sync` re-exports the instrumented lock/atomic
//! types from `util::sync::model`, and the [`Checker`] drives every
//! reachable sequentially-consistent interleaving of the small thread
//! programs below.  Three protocols the serving stack bets on:
//!
//! 1. **Engine hot swap** — `Slot` readers never observe a torn engine
//!    while a recalibration publishes a replacement, and the swap is
//!    visible once all threads join.
//! 2. **Drift single-flight gate** — at most one recalibration is ever
//!    admitted concurrently, the gate reopens after `finish`, and the
//!    recal point is published before the generation bump that
//!    advertises it.
//! 3. **FFT plan cache** — concurrent `PlanCache::get` calls for the
//!    same length converge on one shared plan.
#![cfg(loom)]

use cirptc::circulant::fft::PlanCache;
use cirptc::util::sync::atomic::{AtomicUsize, Ordering};
use cirptc::util::sync::model::Checker;
use cirptc::util::sync::{Arc, Mutex, PoisonError, SingleFlight, Slot};

/// Stand-in for the serving engine: `checksum` is derived from
/// `generation`, so any torn or half-published read breaks the pair.
struct Engine {
    generation: usize,
    checksum: usize,
}

impl Engine {
    fn new(generation: usize) -> Engine {
        Engine { generation, checksum: generation.wrapping_mul(31) + 7 }
    }
}

#[test]
fn slot_hot_swap_readers_never_tear() {
    let summary = Checker::new("slot-hot-swap").check(|run| {
        let slot = Arc::new(Slot::new(Engine::new(0)));
        for _ in 0..2 {
            let slot = Arc::clone(&slot);
            run.thread(move || {
                let engine = slot.current();
                assert_eq!(
                    engine.checksum,
                    engine.generation.wrapping_mul(31) + 7,
                    "reader observed a torn engine"
                );
                assert!(engine.generation <= 1);
            });
        }
        let swapper = Arc::clone(&slot);
        run.thread(move || swapper.swap(Engine::new(1)));
        let after = Arc::clone(&slot);
        run.after(move || {
            assert_eq!(
                after.current().generation,
                1,
                "swap must be visible once every thread joined"
            );
        });
    });
    assert!(summary.schedules >= 2, "only {} schedules explored", summary.schedules);
}

#[test]
fn single_flight_admits_at_most_one_concurrently() {
    let summary = Checker::new("drift-single-flight").check(|run| {
        let gate = Arc::new(SingleFlight::new());
        let inside = Arc::new(AtomicUsize::new(0));
        let completed = Arc::new(AtomicUsize::new(0));
        for _ in 0..2 {
            let gate = Arc::clone(&gate);
            let inside = Arc::clone(&inside);
            let completed = Arc::clone(&completed);
            run.thread(move || {
                if gate.try_begin() {
                    let now_inside = inside.fetch_add(1, Ordering::SeqCst) + 1;
                    assert_eq!(now_inside, 1, "two recalibrations admitted concurrently");
                    completed.fetch_add(1, Ordering::SeqCst);
                    inside.fetch_sub(1, Ordering::SeqCst);
                    gate.finish();
                }
            });
        }
        let gate = Arc::clone(&gate);
        let completed = Arc::clone(&completed);
        run.after(move || {
            assert!(!gate.in_flight(), "gate reopens after the last finish");
            let done = completed.load(Ordering::SeqCst);
            assert!(
                (1..=2).contains(&done),
                "at least one probe must win the gate, {done} completed"
            );
        });
    });
    assert!(summary.schedules >= 2, "only {} schedules explored", summary.schedules);
}

#[test]
fn recal_point_published_before_generation_bump() {
    Checker::new("drift-recal-ordering").check(|run| {
        let point = Arc::new(Mutex::new(None::<usize>));
        let generation = Arc::new(AtomicUsize::new(0));
        let w_point = Arc::clone(&point);
        let w_gen = Arc::clone(&generation);
        run.thread(move || {
            // recal worker: store the new operating point, then bump the
            // generation that advertises it (recal.rs order)
            *w_point.lock().unwrap_or_else(PoisonError::into_inner) = Some(42);
            w_gen.store(1, Ordering::SeqCst);
        });
        let r_point = Arc::clone(&point);
        let r_gen = Arc::clone(&generation);
        run.thread(move || {
            // monitor: a bumped generation implies the point is readable
            if r_gen.load(Ordering::SeqCst) == 1 {
                let p = *r_point.lock().unwrap_or_else(PoisonError::into_inner);
                assert_eq!(p, Some(42), "generation advertised before its recal point");
            }
        });
    });
}

#[test]
fn plan_cache_converges_on_one_plan_per_length() {
    Checker::new("fft-plan-cache").check(|run| {
        let cache = Arc::new(PlanCache::new());
        let grabbed = Arc::new(Mutex::new(Vec::new()));
        for _ in 0..2 {
            let cache = Arc::clone(&cache);
            let grabbed = Arc::clone(&grabbed);
            run.thread(move || {
                let plan = cache.get(8);
                assert_eq!(plan.len(), 8);
                grabbed.lock().unwrap_or_else(PoisonError::into_inner).push(plan);
            });
        }
        let other_len = Arc::clone(&cache);
        run.thread(move || {
            assert_eq!(other_len.get(4).len(), 4, "interleaved other-length get");
        });
        let cache = Arc::clone(&cache);
        let grabbed = Arc::clone(&grabbed);
        run.after(move || {
            let got = grabbed.lock().unwrap_or_else(PoisonError::into_inner);
            assert_eq!(got.len(), 2);
            assert!(
                Arc::ptr_eq(&got[0], &got[1]),
                "racing gets for one length must share one plan"
            );
            assert!(Arc::ptr_eq(&got[0], &cache.get(8)), "cache still serves the same plan");
        });
    });
}
