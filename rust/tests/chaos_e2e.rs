//! Chaos end-to-end: the self-healing farm under seeded fault injection
//! (DESIGN.md §fault).
//!
//! * **pinned scenario** — a 3-member supervised farm where member 0
//!   takes a DeadChip episode (silent: only probes notice) and member 1
//!   a TransientPassError episode (detectable: batches fail and are
//!   retried on siblings).  The run must auto-quarantine, retry, and
//!   auto-restore with `completed == submitted` and zero rejections —
//!   no operator action anywhere.
//! * **randomized propcheck** — farms under `FaultPlan::generate(seed)`
//!   schedules (every member on its own noise stream) never drop or
//!   reject a request, never surface an error to a caller, and recover
//!   to a serving majority once the episodes end.
//!
//! Everything is seeded; loops synchronize on metrics and health state
//! with generous deadlines, never on sleeps alone.

use std::sync::Arc;
use std::time::{Duration, Instant};

use cirptc::coordinator::{worker, BatcherConfig, InferenceBackend, Metrics};
use cirptc::data::datasets::{self, SHAPES_MANIFEST_JSON};
use cirptc::drift::{DriftMonitor, MonitorConfig};
use cirptc::farm::{
    ChipHealth, ChipStatus, Farm, FarmConfig, FarmMember,
    DEFAULT_DRIFTING_PPM,
};
use cirptc::fault::{
    ChipSupervisor, Episode, FaultKind, FaultPlan, SupervisorConfig,
};
use cirptc::onn::{Engine, Manifest};
use cirptc::simulator::{ChipDescription, ChipSim};
use cirptc::tensor::Tensor;
use cirptc::train::TrainModel;
use cirptc::util::propcheck;
use cirptc::util::testing::ConstBackend;

const K: usize = 3;
const CHUNK: usize = 8;

fn chaos_chip(k: usize) -> ChipDescription {
    let mut d = ChipDescription::ideal(4);
    d.w_bits = 6;
    d.x_bits = 4;
    d.dark = 0.01;
    d.seed = 0xCA05 ^ k as u64;
    d
}

fn supervisor_cfg() -> SupervisorConfig {
    SupervisorConfig {
        residual_ceiling: 0.05,
        consecutive_failures: 2,
        probation_probes: 2,
        // episodes end, so probation must eventually succeed; the
        // escalation latch is pinned by fault-module unit tests
        max_probations: 100_000,
    }
}

/// Build a K-member supervised farm (untrained shapes model, fixed
/// deterministic chips) where member `k` runs `plans[k]`, over a
/// constant digital fallback lane.
fn supervised_farm(
    plans: Vec<Option<FaultPlan>>,
    metrics: &Arc<Metrics>,
) -> (Farm, Vec<Arc<ChipStatus>>, Vec<Tensor>) {
    let manifest = Manifest::parse(SHAPES_MANIFEST_JSON).unwrap();
    let model = TrainModel::init(manifest.clone(), 0xCA).unwrap();
    let bundle = model.export_bundle();
    let eval_split = datasets::synth_shapes(32, 0xCB);
    let imgs: Vec<Tensor> =
        (0..eval_split.n).map(|i| eval_split.image(i)).collect();

    let mut members = Vec::with_capacity(plans.len());
    for (k, plan) in plans.into_iter().enumerate() {
        let engine = Engine::from_parts(manifest.clone(), &bundle).unwrap();
        let desc = chaos_chip(k);
        let mut sim = ChipSim::deterministic(desc.clone());
        if let Some(plan) = plan {
            sim.set_fault(plan);
        }
        // monitor-only: probe every batch, never request a recalibration
        // (the supervisor, not the recalibrator, is under test here)
        let monitor = DriftMonitor::new(
            MonitorConfig {
                probe_every: 1,
                residual_trigger: f32::INFINITY,
                ..MonitorConfig::default()
            },
            &desc,
        );
        let (member, recal_rx) = FarmMember::supervised(
            engine,
            sim,
            monitor,
            ChipSupervisor::new(supervisor_cfg()),
            DEFAULT_DRIFTING_PPM,
            Duration::from_millis(2),
            Arc::clone(metrics),
        );
        // monitor-only config never requests a recal; the rx can drop
        drop(recal_rx);
        members.push(member);
    }
    let status: Vec<_> =
        members.iter().map(|m| Arc::clone(&m.status)).collect();
    let fallback: worker::BackendFactory =
        Box::new(|| Box::new(ConstBackend) as Box<dyn InferenceBackend>);
    let farm = Farm::start_with_fallback(
        members,
        Some(fallback),
        FarmConfig {
            batcher: BatcherConfig {
                max_batch: CHUNK,
                max_wait_us: 20_000,
                queue_cap: 0,
            },
            pass_deadline: Some(Duration::from_secs(10)),
            ..FarmConfig::default()
        },
        Arc::clone(metrics),
    );
    (farm, status, imgs)
}

/// One pass of `imgs` through the farm; panics on any dropped request.
fn serve_round(farm: &Farm, imgs: &[Tensor]) {
    for chunk in imgs.chunks(CHUNK) {
        let responses = farm.coord.classify_all(chunk).unwrap();
        assert_eq!(responses.len(), chunk.len(), "request dropped");
    }
}

fn serving_members(status: &[Arc<ChipStatus>]) -> usize {
    status.iter().filter(|st| st.health() != ChipHealth::Failed).count()
}

#[test]
fn dead_chip_and_transient_errors_self_heal_with_zero_drops() {
    let metrics = Arc::new(Metrics::default());
    // member 0: silent total die loss for 40 passes — probes must
    // quarantine it; member 1: detectable garbage passes — batches must
    // be retried on siblings; member 2: clean
    let plans = vec![
        Some(FaultPlan::new(
            0xDead,
            vec![Episode {
                start_pass: 5,
                duration: 40,
                kind: FaultKind::DeadChip,
            }],
        )),
        Some(FaultPlan::new(
            0x7a51,
            vec![Episode {
                start_pass: 0,
                duration: 30,
                kind: FaultKind::TransientPassError { p: 0.8 },
            }],
        )),
        None,
    ];
    let (farm, status, imgs) = supervised_farm(plans, &metrics);

    // serve until the loop closes: at least one automatic quarantine, at
    // least one retry, and every member back to serving health (the
    // episodes are finite, probation restores on idle probes)
    let deadline = Instant::now() + Duration::from_secs(300);
    loop {
        serve_round(&farm, &imgs);
        let healed = metrics.quarantines.get() >= 1
            && metrics.retries.get() >= 1
            && serving_members(&status) == K;
        if healed {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "chaos farm never healed: health {:?}, {}",
            status.iter().map(|s| s.health()).collect::<Vec<_>>(),
            metrics.summary()
        );
    }
    // one more clean round on the restored farm
    serve_round(&farm, &imgs);

    assert_eq!(metrics.rejected.get(), 0, "{}", metrics.summary());
    assert_eq!(
        metrics.completed.get(),
        metrics.submitted.get(),
        "every accepted request must complete: {}",
        metrics.summary()
    );
    assert!(
        metrics.faults_injected.get() >= 1,
        "the plan must actually have corrupted passes: {}",
        metrics.summary()
    );
    assert!(
        !status.iter().any(|st| st.is_quarantined()),
        "no member may stay latched after episodes end"
    );
    drop(farm);
}

#[test]
fn randomized_fault_plans_never_drop_requests_and_recover() {
    propcheck::check("chaos fault-plan robustness", 3, |g| {
        let seed = g.usize_in(1, 1 << 20) as u64;
        let base = FaultPlan::generate(seed);
        let metrics = Arc::new(Metrics::default());
        let plans: Vec<Option<FaultPlan>> = (0..K)
            .map(|k| {
                Some(FaultPlan::new(
                    seed ^ k as u64,
                    base.episodes().to_vec(),
                ))
            })
            .collect();
        let (farm, status, imgs) = supervised_farm(plans, &metrics);

        // generated plans always contain a hard episode, so demand the
        // full loop: quarantine observed, then a serving majority again
        let deadline = Instant::now() + Duration::from_secs(300);
        loop {
            serve_round(&farm, &imgs);
            if metrics.quarantines.get() >= 1
                && serving_members(&status) >= K - 1
            {
                break;
            }
            if Instant::now() >= deadline {
                return Err(format!(
                    "seed {seed}: farm never recovered: health {:?}, {}",
                    status.iter().map(|s| s.health()).collect::<Vec<_>>(),
                    metrics.summary()
                ));
            }
        }
        serve_round(&farm, &imgs);

        if metrics.rejected.get() != 0
            || metrics.completed.get() != metrics.submitted.get()
        {
            return Err(format!(
                "seed {seed}: dropped or rejected requests: {}",
                metrics.summary()
            ));
        }
        drop(farm);
        Ok(())
    });
}
