//! Cross-validation: the rust simulator / engine against golden vectors
//! and artifacts exported by the python build path.  These tests skip
//! gracefully when `make artifacts` has not run yet (CI bootstrap), but
//! the Makefile's `test` target guarantees artifacts exist.

use std::path::PathBuf;

use cirptc::circulant::Bcm;
use cirptc::data::Bundle;
use cirptc::simulator::{ChipDescription, ChipSim};
use cirptc::tensor::Tensor;

fn artifacts() -> Option<PathBuf> {
    // the crate manifest lives in rust/; artifacts/ sits at the workspace
    // root next to benches/ and examples/
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("artifacts");
    dir.join("chip.json").exists().then_some(dir)
}

#[test]
fn chip_json_parses_and_matches_python_export() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let chip = ChipDescription::load(&dir.join("chip.json")).unwrap();
    assert_eq!(chip.l, 4);
    assert_eq!(chip.w_bits, 6);
    assert_eq!(chip.x_bits, 4);
    // Γ rows near-normalised (python normalises then perturbs)
    for i in 0..4 {
        let row: f32 = chip.gamma[i * 4..(i + 1) * 4].iter().sum();
        assert!((row - 1.0).abs() < 0.05, "row {i} sums to {row}");
    }
}

/// The core numerical contract: the rust simulator's deterministic forward
/// must match the python chip model on the exported golden vectors.
#[test]
fn simulator_matches_python_goldens() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let goldens = Bundle::load(&dir.join("goldens.cpt")).unwrap();
    let chip = ChipDescription::load(&dir.join("chip.json")).unwrap();
    let cases: Vec<String> = {
        let mut c: Vec<String> = goldens
            .tensors
            .keys()
            .filter_map(|k| k.strip_suffix(".w").map(String::from))
            .collect();
        c.sort();
        c
    };
    assert!(cases.len() >= 4);
    for case in cases {
        let w = goldens.get(&format!("{case}.w")).unwrap();
        let x = goldens.get(&format!("{case}.x")).unwrap();
        let y = goldens.get(&format!("{case}.y")).unwrap();
        let ws = w.shape().to_vec();
        let (p, q, l) = (ws[0], ws[1], ws[2]);
        let bcm = Bcm::new(p, q, l, w.as_f32().unwrap().to_vec());
        let xt = Tensor::new(x.shape(), x.as_f32().unwrap().to_vec());
        let got = if l == chip.l {
            let mut sim = ChipSim::deterministic(chip.clone());
            sim.forward(&bcm, &xt)
        } else {
            // python used the pure crossbar_forward_ref (no chip instance)
            // for off-order cases: quantize only, identity Γ, no tilt
            let mut ideal = ChipDescription::ideal(l);
            ideal.w_bits = 6;
            ideal.x_bits = 4;
            let mut sim = ChipSim::deterministic(ideal);
            sim.forward(&bcm, &xt)
        };
        let want = y.as_f32().unwrap();
        let max_diff = got
            .data
            .iter()
            .zip(want)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
        assert!(
            max_diff < 2e-3,
            "case {case}: rust sim vs python chip max |Δ| = {max_diff}"
        );
    }
}

#[test]
fn trained_model_bundles_load_into_engine() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    for model in ["synth_cxr", "synth_digits", "synth_textures"] {
        let manifest = dir.join(format!("models/{model}.json"));
        let bundle = dir.join(format!("models/{model}_dpe.cpt"));
        if !manifest.exists() {
            eprintln!("skipping {model}: train.py not run");
            continue;
        }
        let engine = cirptc::onn::Engine::load(&manifest, &bundle).unwrap();
        let (c, h) = engine.manifest.input_shape();
        let img = Tensor::full(&[c, h, h], 0.5);
        let logits = engine
            .forward(&img, &mut cirptc::onn::Backend::Digital)
            .unwrap();
        assert_eq!(logits.len(), engine.manifest.classes);
        assert!(logits.iter().all(|v| v.is_finite()));
    }
}

/// Compressed-parameter accounting matches the paper's ~74.9 % reduction.
#[test]
fn parameter_reduction_from_manifests() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    for model in ["synth_cxr", "synth_digits", "synth_textures"] {
        let path = dir.join(format!("models/{model}.json"));
        if !path.exists() {
            continue;
        }
        let m = cirptc::onn::Manifest::load(&path).unwrap();
        let (dense, stored) = m.param_counts();
        let reduction = 100.0 * (1.0 - stored as f64 / dense as f64);
        assert!(
            (74.0..=75.0).contains(&reduction),
            "{model}: reduction {reduction:.2}% (paper: 74.91%)"
        );
    }
}
