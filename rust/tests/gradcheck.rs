//! Finite-difference gradient checks for every backward kernel of the
//! training subsystem (ISSUE 3 acceptance: rel. err ≤ 1e-3 on random
//! small shapes, driven through `util::propcheck`).
//!
//! Strategy: project each kernel's output onto a fixed random direction
//! `R` so the scalar loss `L = Σ y ⊙ R` has the kernel's adjoint as its
//! exact gradient, then compare against central differences.  Linear
//! kernels (BCM multiply, im2col/col2im) admit large steps — the
//! difference quotient is exact up to f32 rounding; the nonlinear ones
//! (batch-norm, max-pool, the full model) use small steps and
//! well-separated inputs.

use cirptc::circulant::Bcm;
use cirptc::onn::Manifest;
use cirptc::simulator::{ChipDescription, ChipSim};
use cirptc::tensor::{self, Tensor};
use cirptc::train::{softmax_cross_entropy, TrainBackend, TrainModel};
use cirptc::util::propcheck::{self, assert_close};
use cirptc::util::rng::Rng;

/// |analytic − numeric| ≤ 1e-3 · max(1, |analytic|, |numeric|).
fn grad_close(analytic: f32, numeric: f32) -> bool {
    (analytic - numeric).abs()
        <= 1e-3 * analytic.abs().max(numeric.abs()).max(1.0)
}

fn dot(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (*x as f64) * (*y as f64)).sum()
}

#[test]
fn bcm_backward_dw_and_dx_match_central_differences() {
    propcheck::check("bcm backward vs fd", 25, |g| {
        let (p, q) = (g.usize_in(1, 3), g.usize_in(1, 3));
        let l = *g.choose(&[2usize, 4, 8]);
        let cols = g.usize_in(1, 4);
        let bcm = Bcm::new(p, q, l, g.vec_f32(p * q * l, -1.0, 1.0));
        let x = Tensor::new(&[bcm.n(), cols], g.vec_f32(bcm.n() * cols, -1.0, 1.0));
        let r = Tensor::new(&[bcm.m(), cols], g.vec_f32(bcm.m() * cols, -1.0, 1.0));
        let (dw, dx) = bcm.backward(&x, &r);
        // exactly linear in both w and x: big step, rounding-limited fd
        let h = 0.1f32;
        let loss_w = |b: &Bcm| dot(&b.mmm(&x, 1).data, &r.data);
        for i in 0..bcm.w.len() {
            let mut bp = bcm.clone();
            bp.w[i] += h;
            let mut bm = bcm.clone();
            bm.w[i] -= h;
            let fd = ((loss_w(&bp) - loss_w(&bm)) / (2.0 * h as f64)) as f32;
            if !grad_close(dw[i], fd) {
                return Err(format!("dw[{i}]: {} vs fd {fd}", dw[i]));
            }
        }
        let loss_x = |xt: &Tensor| dot(&bcm.mmm(xt, 1).data, &r.data);
        for i in 0..x.numel() {
            let mut xp = x.clone();
            xp.data[i] += h;
            let mut xm = x.clone();
            xm.data[i] -= h;
            let fd = ((loss_x(&xp) - loss_x(&xm)) / (2.0 * h as f64)) as f32;
            if !grad_close(dx.data[i], fd) {
                return Err(format!("dx[{i}]: {} vs fd {fd}", dx.data[i]));
            }
        }
        Ok(())
    });
}

#[test]
fn fft_backward_equals_direct_backward() {
    propcheck::check("fft adjoint == direct adjoint", 40, |g| {
        let (p, q) = (g.usize_in(1, 4), g.usize_in(1, 4));
        let l = *g.choose(&[2usize, 4, 8, 16]);
        let cols = g.usize_in(1, 5);
        let bcm = Bcm::new(p, q, l, g.vec_f32(p * q * l, -1.0, 1.0));
        let x = Tensor::new(&[bcm.n(), cols], g.vec_f32(bcm.n() * cols, -1.0, 1.0));
        let dy = Tensor::new(&[bcm.m(), cols], g.vec_f32(bcm.m() * cols, -1.0, 1.0));
        let (dw_d, dx_d) = bcm.mmm_backward(&x, &dy);
        let (dw_f, dx_f) = bcm.mmm_fft_backward(&x, &dy);
        assert_close(&dw_f, &dw_d, 1e-3)?;
        assert_close(&dx_f.data, &dx_d.data, 1e-3)
    });
}

#[test]
fn col2im_matches_central_differences_of_im2col() {
    propcheck::check("col2im vs fd", 20, |g| {
        let (b, c) = (g.usize_in(1, 2), g.usize_in(1, 2));
        let (h, w) = (g.usize_in(3, 5), g.usize_in(3, 5));
        let k = 3usize;
        let x = Tensor::new(&[b, c, h, w], g.vec_f32(b * c * h * w, -1.0, 1.0));
        let r = {
            let rows = c * k * k;
            let cols = b * h * w;
            Tensor::new(&[rows, cols], g.vec_f32(rows * cols, -1.0, 1.0))
        };
        // analytic: dL/dx = col2im(R) for L = <im2col(x), R>
        let dx = tensor::col2im_same_batch(&r, b, c, h, w, k);
        let loss =
            |xt: &Tensor| dot(&tensor::im2col_same_batch(xt, k).data, &r.data);
        let hstep = 0.1f32; // linear in x: exact at any step
        for i in 0..x.numel() {
            let mut xp = x.clone();
            xp.data[i] += hstep;
            let mut xm = x.clone();
            xm.data[i] -= hstep;
            let fd =
                ((loss(&xp) - loss(&xm)) / (2.0 * hstep as f64)) as f32;
            if !grad_close(dx.data[i], fd) {
                return Err(format!("dx[{i}]: {} vs fd {fd}", dx.data[i]));
            }
        }
        Ok(())
    });
}

#[test]
fn maxpool_backward_matches_central_differences() {
    // well-separated inputs (multiples of 0.05, shuffled) so a small step
    // can't flip any window's argmax; within the region the op is linear
    let (b, c, h, w, p) = (2usize, 2usize, 4usize, 4usize, 2usize);
    let n = b * c * h * w;
    let mut rng = Rng::new(99);
    let perm = rng.permutation(n);
    let mut xd = vec![0.0f32; n];
    for (i, &pi) in perm.iter().enumerate() {
        xd[i] = pi as f32 * 0.05;
    }
    let x = Tensor::new(&[b, c, h, w], xd);
    let (y, argmax) = tensor::maxpool_batch_argmax(&x, p);
    let mut r = vec![0.0f32; y.numel()];
    rng.fill_uniform(&mut r);
    let rt = Tensor::new(&y.shape, r);
    let dx = tensor::maxpool_batch_backward(&rt, &argmax, &x.shape);
    let hstep = 1e-3f32;
    for i in 0..x.numel() {
        let mut xp = x.clone();
        xp.data[i] += hstep;
        let mut xm = x.clone();
        xm.data[i] -= hstep;
        let lp = dot(&tensor::maxpool_batch(&xp, p).data, &rt.data);
        let lm = dot(&tensor::maxpool_batch(&xm, p).data, &rt.data);
        let fd = ((lp - lm) / (2.0 * hstep as f64)) as f32;
        assert!(
            grad_close(dx.data[i], fd),
            "dx[{i}]: {} vs fd {fd}",
            dx.data[i]
        );
    }
}

#[test]
fn batchnorm_backward_matches_central_differences() {
    propcheck::check("bn backward vs fd", 10, |g| {
        let (b, c) = (2usize, g.usize_in(1, 2));
        let (h, w) = (3usize, 3usize);
        let x = Tensor::new(&[b, c, h, w], g.vec_f32(b * c * h * w, -1.5, 1.5));
        let r = Tensor::new(&[b, c, h, w], g.vec_f32(b * c * h * w, -1.0, 1.0));
        let gamma = g.vec_f32(c, 0.5, 1.5);
        let beta = g.vec_f32(c, -0.5, 0.5);
        let eps = 1e-5f32;
        let loss = |xt: &Tensor| {
            let (y, _, _) = tensor::batchnorm_train(xt, &gamma, &beta, eps);
            dot(&y.data, &r.data)
        };
        let (_, xhat, stats) = tensor::batchnorm_train(&x, &gamma, &beta, eps);
        let (dx, _, _) = tensor::batchnorm_backward(&r, &xhat, &gamma, &stats);
        let hstep = 1e-2f32;
        for i in 0..x.numel() {
            let mut xp = x.clone();
            xp.data[i] += hstep;
            let mut xm = x.clone();
            xm.data[i] -= hstep;
            let fd =
                ((loss(&xp) - loss(&xm)) / (2.0 * hstep as f64)) as f32;
            if !grad_close(dx.data[i], fd) {
                return Err(format!("dx[{i}]: {} vs fd {fd}", dx.data[i]));
            }
        }
        Ok(())
    });
}

const TINY: &str = r#"{
  "dataset": "synth_shapes", "classes": 3,
  "layers": [
    {"kind": "conv", "cin": 1, "cout": 4, "k": 3, "pool": 2,
     "arch": "circ", "l": 4, "act_scale": 8.0},
    {"kind": "bn", "cin": 4, "cout": 0, "k": 3, "pool": 2,
     "arch": "circ", "l": 4, "act_scale": 8.0},
    {"kind": "relu", "cin": 0, "cout": 0, "k": 3, "pool": 2,
     "arch": "circ", "l": 4, "act_scale": 8.0},
    {"kind": "pool", "cin": 0, "cout": 0, "k": 3, "pool": 2,
     "arch": "circ", "l": 4, "act_scale": 8.0},
    {"kind": "flatten", "cin": 0, "cout": 0, "k": 3, "pool": 2,
     "arch": "circ", "l": 4, "act_scale": 8.0},
    {"kind": "fc", "cin": 64, "cout": 3, "k": 3, "pool": 2,
     "arch": "circ", "l": 4, "act_scale": 8.0}
  ]}"#;

fn tiny_batch(n: usize, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let mut d = vec![0.0f32; n * 8 * 8];
    rng.fill_uniform(&mut d);
    Tensor::new(&[n, 1, 8, 8], d)
}

/// Directional derivative of the full-model cross-entropy against the
/// analytic backward pass, per parameter tensor.  The composition stacks
/// every kernel (conv im2col/BCM, bn, relu, pool, fc), so a looser 1e-2
/// tolerance absorbs the f32 forward's rounding in the quotient.
#[test]
fn full_model_directional_gradcheck_digital() {
    let model =
        TrainModel::init(Manifest::parse(TINY).unwrap(), 31).unwrap();
    let xb = tiny_batch(3, 32);
    let labels = [0u8, 1, 2];
    let mut dir_rng = Rng::new(33);

    // analytic gradients
    let mut m0 = model.clone();
    let pass = m0.forward_train(&xb, &mut TrainBackend::Digital).unwrap();
    let (_, dl) = softmax_cross_entropy(&pass.logits, &labels);
    let grads = m0.backward(&pass, &dl).unwrap();

    // loss as a function of a perturbed clone (BN batch-stats mode, which
    // is what the analytic gradients differentiate)
    let eval = |m: &TrainModel| -> f64 {
        let mut mc = m.clone();
        let pass = mc
            .forward_train(&xb, &mut TrainBackend::Digital)
            .unwrap();
        let (loss, _) = softmax_cross_entropy(&pass.logits, &labels);
        loss as f64
    };

    let h = 1e-2f32;
    for (li, g) in grads.per_layer.iter().enumerate() {
        let tensors: Vec<Vec<f32>> = match g {
            cirptc::train::LayerGrad::Linear { dw, db } => {
                vec![dw.clone(), db.clone()]
            }
            cirptc::train::LayerGrad::Bn { dgamma, dbeta } => {
                vec![dgamma.clone(), dbeta.clone()]
            }
            cirptc::train::LayerGrad::None => continue,
        };
        for (pi, gvec) in tensors.iter().enumerate() {
            // unit random direction
            let mut v = vec![0.0f32; gvec.len()];
            dir_rng.fill_normal(&mut v, 1.0);
            let norm =
                (v.iter().map(|a| (a * a) as f64).sum::<f64>()).sqrt() as f32;
            for a in v.iter_mut() {
                *a /= norm.max(1e-9);
            }
            let proj: f64 = gvec
                .iter()
                .zip(&v)
                .map(|(a, b)| (*a as f64) * (*b as f64))
                .sum();
            let perturb = |sign: f32| -> TrainModel {
                let mut m = model.clone();
                apply_direction(&mut m, li, pi, &v, sign * h);
                m
            };
            let fd = (eval(&perturb(1.0)) - eval(&perturb(-1.0)))
                / (2.0 * h as f64);
            assert!(
                (proj - fd).abs() <= 2e-2 * proj.abs().max(fd.abs()).max(0.1),
                "layer {li} param {pi}: directional {proj} vs fd {fd}"
            );
        }
    }
}

/// Add `scale * v` to parameter tensor `pi` (0 = weights/gamma,
/// 1 = bias/beta) of layer `li`.
fn apply_direction(
    m: &mut TrainModel,
    li: usize,
    pi: usize,
    v: &[f32],
    scale: f32,
) {
    use cirptc::train::model::TrainLayer;
    match &mut m.layers[li] {
        TrainLayer::Linear(lin) => {
            let p = if pi == 0 { &mut lin.bcm.w } else { &mut lin.bias };
            for (a, b) in p.iter_mut().zip(v) {
                *a += scale * b;
            }
        }
        TrainLayer::Bn(bn) => {
            let p = if pi == 0 { &mut bn.gamma } else { &mut bn.beta };
            for (a, b) in p.iter_mut().zip(v) {
                *a += scale * b;
            }
        }
        TrainLayer::Stateless => {}
    }
}

/// With an ideal chip (identity Γ, 0-bit DACs, no noise) the
/// chip-in-the-loop surrogate gradients must coincide with the digital
/// ones — the STE/clamp machinery reduces to the identity on in-range
/// activations.
#[test]
fn chip_ideal_gradients_match_digital() {
    let model =
        TrainModel::init(Manifest::parse(TINY).unwrap(), 41).unwrap();
    let xb = tiny_batch(2, 42);
    let labels = [1u8, 2];

    let mut md = model.clone();
    let pass_d = md.forward_train(&xb, &mut TrainBackend::Digital).unwrap();
    let (_, dl_d) = softmax_cross_entropy(&pass_d.logits, &labels);
    let g_d = md.backward(&pass_d, &dl_d).unwrap();

    let mut mc = model.clone();
    let mut chip =
        TrainBackend::Chip(ChipSim::deterministic(ChipDescription::ideal(4)));
    let pass_c = mc.forward_train(&xb, &mut chip).unwrap();
    let (_, dl_c) = softmax_cross_entropy(&pass_c.logits, &labels);
    let g_c = mc.backward(&pass_c, &dl_c).unwrap();

    for (a, b) in g_d.per_layer.iter().zip(&g_c.per_layer) {
        match (a, b) {
            (
                cirptc::train::LayerGrad::Linear { dw: dwa, db: dba },
                cirptc::train::LayerGrad::Linear { dw: dwb, db: dbb },
            ) => {
                assert_close(dwa, dwb, 1e-3).unwrap();
                assert_close(dba, dbb, 1e-3).unwrap();
            }
            (
                cirptc::train::LayerGrad::Bn { dgamma: ga, dbeta: ba },
                cirptc::train::LayerGrad::Bn { dgamma: gb, dbeta: bb },
            ) => {
                assert_close(ga, gb, 1e-3).unwrap();
                assert_close(ba, bb, 1e-3).unwrap();
            }
            (
                cirptc::train::LayerGrad::None,
                cirptc::train::LayerGrad::None,
            ) => {}
            _ => panic!("grad structure diverged between backends"),
        }
    }
}
