//! End-to-end tests of the static artifact validator (DESIGN.md §verify)
//! against the committed fixture set in `tests/fixtures/verify/`
//! (regenerate with `gen_fixtures.py` — deterministic, byte-stable).
//!
//! One corrupt fixture per validator pass proves each pass actually
//! fires, with the diagnostic attributed to the right layer; the valid
//! fixture proves the pipeline is read-only (byte-for-byte unchanged
//! files) and accepted by `Engine::from_parts`.

use std::fs;
use std::path::{Path, PathBuf};

use cirptc::data::Bundle;
use cirptc::onn::{Engine, Manifest};
use cirptc::simulator::ChipDescription;
use cirptc::verify::passes::check_spectra;
use cirptc::verify::{validate_artifacts, Report};

fn fixture(name: &str) -> PathBuf {
    Path::new("tests/fixtures/verify").join(name)
}

fn validate_pair(manifest: &str, bundle: &str) -> Report {
    let m = Manifest::load(&fixture(manifest)).expect(manifest);
    let b = Bundle::load(&fixture(bundle)).expect(bundle);
    validate_artifacts(&m, &b, None)
}

/// Assert the report rejects the artifacts with at least one diagnostic
/// from `pass` attributed to `layer`.
fn assert_rejected(report: &Report, pass: &str, layer: Option<usize>) {
    assert!(!report.is_ok(), "corrupt artifacts accepted");
    let hit = report
        .diagnostics
        .iter()
        .any(|d| d.pass == pass && d.layer == layer);
    assert!(
        hit,
        "expected a [{pass}] diagnostic for layer {layer:?}, got:\n{}",
        report.json_dump()
    );
}

#[test]
fn valid_fixture_passes_and_files_are_untouched() {
    let paths = ["valid_model.json", "valid_model.cpt", "chip.json"];
    let before: Vec<Vec<u8>> = paths
        .iter()
        .map(|p| fs::read(fixture(p)).expect(p))
        .collect();

    let manifest = Manifest::load(&fixture("valid_model.json")).expect("manifest");
    let bundle = Bundle::load(&fixture("valid_model.cpt")).expect("bundle");
    let chip = ChipDescription::load(&fixture("chip.json")).expect("chip");
    let report = validate_artifacts(&manifest, &bundle, Some(&chip));
    assert!(report.is_ok(), "valid fixture rejected:\n{}", report.json_dump());

    for (p, snap) in paths.iter().zip(&before) {
        let after = fs::read(fixture(p)).expect(p);
        assert_eq!(&after, snap, "{p} changed during validation");
    }
}

#[test]
fn engine_accepts_valid_and_refuses_corrupt_artifacts() {
    let manifest = Manifest::load(&fixture("valid_model.json")).expect("manifest");
    let bundle = Bundle::load(&fixture("valid_model.cpt")).expect("bundle");
    Engine::from_parts(manifest.clone(), &bundle).expect("valid artifacts build");

    let corrupt = Bundle::load(&fixture("corrupt_blocks.cpt")).expect("bundle");
    let err = match Engine::from_parts(manifest, &corrupt) {
        Ok(_) => panic!("corrupt bundle accepted"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("invalid artifacts"), "unexpected error: {msg}");
    assert!(msg.contains("blocks") || msg.contains("tensors"), "unattributed: {msg}");
}

#[test]
fn graph_pass_catches_channel_mismatch() {
    // bn declares 8 channels right after a cout=4 conv
    let report = validate_pair("corrupt_graph.json", "valid_model.cpt");
    assert_rejected(&report, "graph", Some(1));
}

#[test]
fn blocks_pass_catches_indivisible_padding() {
    // layer5.w grid [1,13,5]: n_pad 65 is not a multiple of l=4
    let report = validate_pair("valid_model.json", "corrupt_blocks.cpt");
    assert_rejected(&report, "blocks", Some(5));
}

#[test]
fn quantizer_pass_catches_infinite_scale() {
    // act_scale 1e999 overflows to +inf at parse time
    let report = validate_pair("corrupt_quant.json", "valid_model.cpt");
    assert_rejected(&report, "quantizer", Some(5));
}

#[test]
fn artifacts_pass_catches_dangling_layer_reference() {
    // layer9.w in a 6-layer model
    let report = validate_pair("valid_model.json", "corrupt_dangling.cpt");
    assert!(!report.is_ok());
    let hit = report
        .diagnostics
        .iter()
        .any(|d| d.pass == "artifacts" && d.field.contains("layer9"));
    assert!(hit, "no dangling-reference diagnostic:\n{}", report.json_dump());
}

#[test]
fn spectra_pass_catches_wrong_spectra_length() {
    // layer5.w [1,16,8] implies 256 spectrum values, the manifest's
    // l=4 grid implies 128
    let report = validate_pair("valid_model.json", "corrupt_spectra.cpt");
    assert_rejected(&report, "spectra", Some(5));
}

#[test]
fn partition_pass_catches_undersized_mrr_bank() {
    // chip_tiny_mrr.json declares an 8-tile bank; layer5's block-row is
    // Q=16 tiles, so no farm width can serve the model
    let manifest = Manifest::load(&fixture("valid_model.json")).expect("manifest");
    let bundle = Bundle::load(&fixture("valid_model.cpt")).expect("bundle");
    let chip = ChipDescription::load(&fixture("chip_tiny_mrr.json")).expect("chip");
    let report = validate_artifacts(&manifest, &bundle, Some(&chip));
    assert_rejected(&report, "partition", Some(5));
    // the legacy chip (no mrr_capacity → unlimited) stays accepted, so
    // the pass only fires on an actual declared bank
    let ok = ChipDescription::load(&fixture("chip.json")).expect("chip");
    let report = validate_artifacts(&manifest, &bundle, Some(&ok));
    assert!(report.is_ok(), "unlimited bank rejected:\n{}", report.json_dump());
}

#[test]
fn nan_act_scale_is_rejected_in_memory() {
    // JSON cannot carry NaN, so this corruption class is in-memory only
    let mut manifest = Manifest::load(&fixture("valid_model.json")).expect("manifest");
    let bundle = Bundle::load(&fixture("valid_model.cpt")).expect("bundle");
    manifest.layers[5].act_scale = f32::NAN;
    let report = validate_artifacts(&manifest, &bundle, None);
    assert_rejected(&report, "quantizer", Some(5));
}

#[test]
fn conjugate_symmetry_violations_are_attributed() {
    let l = 8;
    // a legitimate real-signal spectrum block: re mirrored, im anti-
    // mirrored with im[0] = im[l/2] = 0
    let re = [4.0f32, 1.0, 2.0, 3.0, 9.0, 3.0, 2.0, 1.0];
    let im = [0.0f32, 5.0, 6.0, 7.0, 0.0, -7.0, -6.0, -5.0];
    let mut data: Vec<f32> = re.iter().chain(im.iter()).copied().collect();
    assert!(check_spectra(Some(3), l, 1, &data).is_empty(), "clean block flagged");

    data[l] = 1.0; // im[0] must stay (numerically) zero
    let diags = check_spectra(Some(3), l, 1, &data);
    assert!(!diags.is_empty(), "broken symmetry not flagged");
    assert!(diags.iter().all(|d| d.pass == "spectra" && d.layer == Some(3)));

    // wrong total length is its own diagnostic
    let short = vec![0.0f32; 2 * l - 2];
    assert!(!check_spectra(None, l, 1, &short).is_empty());
}
