//! Feature-matrix regression: the default (no-feature) build must stay
//! free of `xla` references outside `pjrt`-gated regions, so the crate
//! builds hermetically offline.
//!
//! `cargo build` itself enforces linkage (the `xla` dependency is
//! optional), but an ungated call site would only fail once someone
//! built without the feature; this test makes the *source* discipline
//! explicit and fails with a readable message in every configuration.
//! It also pins the `available()` I/O-error contract (satellite of the
//! same bugfix PR).

use std::fs;
use std::path::{Path, PathBuf};

fn rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    for entry in fs::read_dir(dir).unwrap() {
        let path = entry.unwrap().path();
        if path.is_dir() {
            rs_files(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// Every `xla::` occurrence in `text` must belong to a top-level item
/// carrying `#[cfg(feature = "pjrt")]`: the nearest gate before the use
/// must come *after* the last top-level item closed before it (a `}` at
/// column 0), i.e. be attached to the item the use sits in.
fn assert_inline_gated(rel: &str, text: &str) {
    const GATE: &str = "#[cfg(feature = \"pjrt\")]";
    let mut search = 0;
    while let Some(off) = text[search..].find("xla::") {
        let pos = search + off;
        let head = &text[..pos];
        let last_gate = head.rfind(GATE);
        let last_item_close = head.rfind("\n}").unwrap_or(0);
        assert!(
            last_gate.is_some_and(|g| g > last_item_close),
            "src/{rel}: `xla::` use at byte {pos} is not inside a \
             #[cfg(feature = \"pjrt\")]-gated item"
        );
        search = pos + "xla::".len();
    }
}

#[test]
fn xla_references_are_pjrt_gated() {
    let src = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src");
    let mut files = Vec::new();
    rs_files(&src, &mut files);
    assert!(files.len() > 30, "source walk found too few files");
    for path in files {
        let text = fs::read_to_string(&path).unwrap();
        if !text.contains("xla::") {
            continue;
        }
        let rel = path
            .strip_prefix(&src)
            .unwrap()
            .to_string_lossy()
            .replace('\\', "/");
        match rel.as_str() {
            // the whole submodule is compiled only under the feature
            "runtime/pjrt.rs" => {
                let gate = fs::read_to_string(src.join("runtime/mod.rs")).unwrap();
                assert!(
                    gate.contains("#[cfg(feature = \"pjrt\")]\nmod pjrt;"),
                    "runtime/pjrt.rs must stay feature-gated in runtime/mod.rs"
                );
            }
            // inline gates: every xla use must sit in a gated item
            "coordinator/worker.rs" | "util/error.rs" => {
                assert_inline_gated(&rel, &text);
            }
            other => panic!(
                "src/{other} references `xla::` but is not a known pjrt-gated \
                 file; gate it behind `#[cfg(feature = \"pjrt\")]` and extend \
                 this test"
            ),
        }
    }
}

#[test]
fn available_artifacts_errors_on_missing_dir() {
    // the seed silently flattened read_dir errors into "no artifacts";
    // a missing/unreadable dir must now be diagnosable
    let err = cirptc::runtime::available_artifacts(Path::new(
        "/definitely/not/a/real/artifacts/dir",
    ))
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("listing artifacts dir"),
        "error must carry the directory context, got: {msg}"
    );
}

#[test]
fn available_artifacts_lists_sorted_hlo_names() {
    let dir = std::env::temp_dir().join("cirptc_feature_matrix_artifacts");
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    for f in ["b.hlo.txt", "a.hlo.txt", "notes.md"] {
        fs::write(dir.join(f), "x").unwrap();
    }
    let names = cirptc::runtime::available_artifacts(&dir).unwrap();
    assert_eq!(names, vec!["a".to_string(), "b".to_string()]);
}
