//! End-to-end observability (DESIGN.md §obs): one process-owned trace
//! recorder + telemetry sampler over a live drift-recalibrating
//! coordinator.
//!
//! Pins the dynamic side of what repo_lint pins statically:
//!
//! * spans from every instrumented layer of this scenario — request
//!   admission, batch formation, worker inference, drift probes and a
//!   full `recalibrate` span with its `hot_swap` instant — land in the
//!   recorder while requests keep flowing with zero drops;
//! * the written Chrome trace-event file round-trips through the JSON
//!   parser with the exact event shape `chrome://tracing` expects;
//! * the sampler's JSONL stream parses line-by-line, carries the
//!   structured `Metrics::export()` snapshot, and tags the tick where
//!   the recalibration counter advanced with `"event":"recalibration"`.
//!
//! This is the one integration test that owns the process-global
//! recorder (`trace::install` is install-once), so it stays a single
//! `#[test]` — everything else in the file is a helper.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use cirptc::coordinator::{
    BackendFactory, BatcherConfig, Coordinator, InferenceBackend, Metrics,
};
use cirptc::data::datasets::{self, Split, SHAPES_MANIFEST_JSON};
use cirptc::drift::{
    DriftBackend, DriftConfig, DriftModel, DriftMonitor, DriftShared,
    MonitorConfig, RecalConfig, Recalibrator, RecalRequest,
};
use cirptc::obs::sampler::Sampler;
use cirptc::obs::trace;
use cirptc::onn::{Engine, Manifest};
use cirptc::simulator::{ChipDescription, ChipSim};
use cirptc::tensor::Tensor;
use cirptc::train::TrainModel;
use cirptc::util::json::Json;

const CHUNK: usize = 8;

fn chip0() -> ChipDescription {
    let mut d = ChipDescription::ideal(4);
    d.w_bits = 6;
    d.x_bits = 4;
    d.dark = 0.01;
    d.seed = 31;
    d
}

fn drift_cfg() -> DriftConfig {
    DriftConfig {
        seed: 0xE5,
        passes_per_tick: 1,
        gamma_walk: 1.5e-3,
        resp_tilt: 3e-3,
        dark_creep: 2e-4,
        max_ticks: 60,
    }
}

/// One drift-monitored photonic worker with an aggressive trigger, so a
/// recalibration is forced within a few passes.
fn drift_factory(
    shared: &Arc<DriftShared>,
    tx: mpsc::Sender<RecalRequest>,
) -> BackendFactory {
    let shared = Arc::clone(shared);
    Box::new(move || {
        let desc = chip0();
        let mut sim = ChipSim::deterministic(desc.clone());
        sim.set_drift(DriftModel::new(drift_cfg()));
        let mcfg = MonitorConfig {
            probe_every: 1,
            residual_trigger: 1e-6,
            cooldown_passes: 8,
            ..MonitorConfig::default()
        };
        let monitor = DriftMonitor::new(mcfg, &desc);
        Box::new(DriftBackend::new(shared, sim, monitor, tx))
            as Box<dyn InferenceBackend>
    })
}

/// One pass of `eval` through the live coordinator in chunks of 8;
/// panics on any dropped request.
fn serve_round(coord: &Coordinator, eval: &Split) {
    let mut s = 0usize;
    while s < eval.n {
        let e = (s + CHUNK).min(eval.n);
        let imgs: Vec<Tensor> = (s..e).map(|i| eval.image(i)).collect();
        let responses = coord.classify_all(&imgs).unwrap();
        assert_eq!(responses.len(), imgs.len(), "request dropped");
        s = e;
    }
}

#[test]
fn tracing_and_sampler_observe_a_live_recalibration() {
    let rec = trace::TraceRecorder::new(1 << 14);
    assert!(trace::install(Arc::clone(&rec)), "first install wins");
    trace::set_enabled(true);

    // tiny untrained model: accuracy is not under test, the obs plumbing
    // is identical (same idiom as the pipelined drift e2e)
    let manifest = Manifest::parse(SHAPES_MANIFEST_JSON).unwrap();
    let eval_split = datasets::synth_shapes(48, 0xE1);
    let calib_split = datasets::synth_shapes(64, 0xE2);
    let model = TrainModel::init(manifest.clone(), 0xE3).unwrap();
    let bundle = model.export_bundle();
    let metrics = Arc::new(Metrics::default());
    let engine = Engine::from_parts(manifest, &bundle).unwrap();
    let shared = DriftShared::new(engine, Arc::clone(&metrics));

    let (tx, rx) = mpsc::channel();
    let rcfg = RecalConfig {
        fine_tune_steps: 2,
        lr: 2e-3,
        batch: 16,
        bn_batches: 2,
        seed: 0xE4,
        noisy: false,
        snapshot_dir: None,
    };
    let _recal =
        Recalibrator::new(model, calib_split, rcfg, Arc::clone(&shared))
            .spawn(rx);
    let coord = Coordinator::start_with_metrics(
        vec![drift_factory(&shared, tx)],
        BatcherConfig { max_batch: CHUNK, max_wait_us: 20_000, queue_cap: 0 },
        Arc::clone(&metrics),
    );

    let jsonl = std::env::temp_dir()
        .join(format!("cirptc_obs_e2e_{}.jsonl", std::process::id()));
    let smp = Sampler::start(
        &jsonl,
        Duration::from_millis(10),
        Arc::clone(&metrics),
        vec![],
    )
    .expect("start sampler");

    // serve until a recalibration lands (aggressive trigger: a few
    // passes), synchronizing on the shared metrics, never sleeps alone
    let deadline = Instant::now() + Duration::from_secs(120);
    while metrics.recalibrations.get() < 1 {
        serve_round(&coord, &eval_split);
        assert!(
            Instant::now() < deadline,
            "no recalibration landed: {}",
            metrics.summary()
        );
    }
    // a few more sampler ticks so the counter advance is spanned by one
    std::thread::sleep(Duration::from_millis(50));
    smp.stop();
    drop(coord);

    assert_eq!(metrics.errors.get(), 0, "no request may fail");
    assert_eq!(
        metrics.completed.get(),
        metrics.submitted.get(),
        "every accepted request must complete"
    );

    // -- spans: every instrumented layer of this scenario is present ---
    let snap = rec.snapshot();
    for (name, cat) in [
        ("submit", "request"),
        ("batch_form", "request"),
        ("infer", "stage"),
        ("probe", "drift"),
        ("recal_trigger", "drift"),
        ("hot_swap", "drift"),
        ("recalibrate", "drift"),
    ] {
        assert!(
            snap.iter().any(|e| e.name == name && e.cat == cat),
            "missing {cat}/{name} span among {} events",
            snap.len()
        );
    }
    let recal_span = snap
        .iter()
        .find(|e| e.name == "recalibrate")
        .expect("recalibrate span");
    assert!(matches!(recal_span.ph, trace::Phase::Complete));
    assert!(recal_span.dur_us >= 1);

    // -- Chrome trace file round-trips through the parser --------------
    let trace_path = std::env::temp_dir()
        .join(format!("cirptc_obs_e2e_{}.json", std::process::id()));
    rec.write_chrome_trace(&trace_path).expect("write trace");
    let text = std::fs::read_to_string(&trace_path).unwrap();
    let events = Json::parse(&text).expect("trace parses");
    let events = events.as_arr().expect("top-level array");
    assert_eq!(events.len(), snap.len(), "every retained event exported");
    for e in events {
        assert!(e.get("name").and_then(Json::as_str).is_some());
        let ph = e.get("ph").and_then(Json::as_str).unwrap();
        match ph {
            "X" => assert!(e.get("dur").and_then(Json::as_f64).is_some()),
            "i" => assert_eq!(e.get("s").and_then(Json::as_str), Some("t")),
            other => panic!("unexpected ph {other:?}"),
        }
    }

    // -- sampler JSONL: parseable, structured, recal event tagged ------
    let text = std::fs::read_to_string(&jsonl).unwrap();
    let lines: Vec<Json> = text
        .lines()
        .filter(|l| !l.is_empty())
        .map(|l| Json::parse(l).expect("every JSONL line parses"))
        .collect();
    assert!(!lines.is_empty());
    for j in &lines {
        assert!(j.get("t_ms").and_then(Json::as_f64).is_some());
        assert!(
            j.get("metrics")
                .and_then(|m| m.get("counters"))
                .and_then(|c| c.get("submitted"))
                .and_then(Json::as_f64)
                .is_some(),
            "each line carries the structured export"
        );
    }
    assert!(
        lines.iter().any(|j| {
            j.get("event").and_then(Json::as_str) == Some("recalibration")
        }),
        "the recalibration tick must be tagged: {text}"
    );
    let last = lines.last().unwrap();
    assert!(
        last.get("metrics")
            .and_then(|m| m.get("counters"))
            .and_then(|c| c.get("recalibrations"))
            .and_then(Json::as_f64)
            .is_some_and(|r| r >= 1.0),
        "the final sample must show the landed recalibration"
    );

    let _ = std::fs::remove_file(&jsonl);
    let _ = std::fs::remove_file(&trace_path);
}
