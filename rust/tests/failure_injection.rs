//! Failure injection: the serving stack must degrade cleanly, never hang
//! or double-deliver, when backends fail or inputs are malformed.

use std::sync::atomic::AtomicUsize;
use std::sync::Arc;

use cirptc::coordinator::{
    BackendFactory, BatcherConfig, Coordinator, InferenceBackend,
};
use cirptc::tensor::Tensor;
// the misbehaving backends are shared with farm_e2e / chaos_e2e
use cirptc::util::testing::{DeadBackend, FlakyBackend};

fn img() -> Tensor {
    Tensor::full(&[1, 2, 2], 0.5)
}

#[test]
fn failed_batches_are_counted_and_requests_fail_cleanly() {
    let calls = Arc::new(AtomicUsize::new(0));
    let calls2 = Arc::clone(&calls);
    let coord = Coordinator::start(
        vec![Box::new(move || {
            Box::new(FlakyBackend { calls: calls2 }) as Box<dyn InferenceBackend>
        }) as BackendFactory],
        BatcherConfig { max_batch: 4, max_wait_us: 200, queue_cap: 0 },
    );
    let mut ok = 0usize;
    let mut failed = 0usize;
    // submit serially so batches alternate deterministically enough
    for _ in 0..40 {
        match coord.submit(img()).wait() {
            Ok(r) => {
                assert_eq!(r.logits, vec![1.0, 0.0]);
                ok += 1;
            }
            Err(_) => failed += 1, // reply channel closed: clean failure
        }
    }
    assert_eq!(ok + failed, 40, "no request may hang or be lost");
    assert!(ok > 0, "some batches succeed");
    assert!(failed > 0, "some batches fail");
    assert_eq!(coord.metrics.errors.get() + coord.metrics.completed.get(), 40);
}

#[test]
fn dead_backend_fails_everything_without_hanging() {
    let coord = Coordinator::start(
        vec![Box::new(|| Box::new(DeadBackend) as Box<dyn InferenceBackend>)
            as BackendFactory],
        BatcherConfig { max_batch: 8, max_wait_us: 100, queue_cap: 0 },
    );
    for _ in 0..10 {
        assert!(coord.submit(img()).wait().is_err());
    }
    assert_eq!(coord.metrics.errors.get(), 10);
    assert_eq!(coord.metrics.completed.get(), 0);
}

#[test]
fn mixed_healthy_and_dead_workers_still_serve() {
    // with one dead and one healthy worker, throughput drops but every
    // request eventually gets an answer or a clean failure; retrying the
    // failures on the healthy worker must succeed
    let coord = Coordinator::start(
        vec![
            Box::new(|| Box::new(DeadBackend) as Box<dyn InferenceBackend>)
                as BackendFactory,
            Box::new(|| {
                Box::new(FlakyBackend { calls: Arc::new(AtomicUsize::new(0)) })
                    as Box<dyn InferenceBackend>
            }) as BackendFactory,
        ],
        BatcherConfig { max_batch: 2, max_wait_us: 100, queue_cap: 0 },
    );
    let mut answered = 0;
    for _ in 0..30 {
        if coord.submit(img()).wait().is_ok() {
            answered += 1;
        }
    }
    assert!(answered > 0, "healthy worker must still answer");
}

#[test]
fn engine_rejects_mismatched_manifest_and_bundle() {
    use cirptc::data::Bundle;
    use cirptc::onn::{Engine, Manifest};
    let manifest = Manifest::parse(
        r#"{"dataset": "synth_cxr", "classes": 3,
            "layers": [{"kind": "fc", "cin": 16, "cout": 4, "k": 3,
                        "pool": 2, "arch": "circ", "l": 4,
                        "act_scale": 4.0}]}"#,
    )
    .unwrap();
    // bundle missing the layer weights entirely
    let empty = Bundle::default();
    assert!(Engine::from_parts(manifest.clone(), &empty).is_err());
    // bundle with wrong-shaped weights
    let mut bad = Bundle::default();
    bad.insert_f32("layer0.w", &[2, 2, 4], vec![0.0; 16]); // wrong P/Q
    bad.insert_f32("layer0.b", &[4], vec![0.0; 4]);
    assert!(Engine::from_parts(manifest, &bad).is_err());
}

#[test]
fn simulator_rejects_malformed_chip_json() {
    use cirptc::simulator::ChipDescription;
    use cirptc::util::json::Json;
    // gamma shape inconsistent with l
    let j = Json::parse(
        r#"{"l": 4, "gamma_true": [[1, 0], [0, 1]], "resp": [1, 1, 1, 1],
            "dark": 0.0, "sigma_rel": 0.0, "sigma_abs": 0.0,
            "w_bits": 6, "x_bits": 4, "seed": 1}"#,
    )
    .unwrap();
    assert!(ChipDescription::from_json(&j).is_err());
}
