#!/usr/bin/env python3
"""Regenerate the validator fixtures in this directory.

Deterministic (no RNG, no timestamps): running it twice produces
byte-identical files, so `verify_artifacts.rs` can assert the committed
fixtures are exactly what validation ran against.

Model: conv(1->4, k3, circ l4) / bn(4) / relu / pool(2) / flatten /
fc(64->3, circ l4), classes=3.  Block grids (ceil-div): layer0.w
[p=1, q=3, l=4] (n_in = 1*3*3 = 9), layer5.w [p=1, q=16, l=4].

Corrupt variants, one per validator pass under test:
  corrupt_graph.json    bn expects 8 channels after a cout=4 conv
  corrupt_blocks.cpt    layer5.w block grid [1,13,5]: 65 % l(4) != 0
  corrupt_quant.json    fc act_scale = 1e999 -> parses to +inf
  corrupt_dangling.cpt  extra tensor layer9.w for a 6-layer manifest
  corrupt_spectra.cpt   layer5.w [1,16,8]: implied spectra length 256
                        vs the 128 the manifest's l=4 grid implies
  chip_tiny_mrr.json    mrr_capacity 8 < layer5's block-row of Q=16
                        tiles: no farm width can serve the model
"""

import os
import struct

HERE = os.path.dirname(os.path.abspath(__file__))


def val(i):
    """Deterministic pseudo-values in [-0.5, 0.5), exact in f32."""
    return ((i * 37 + 13) % 97) / 97.0 - 0.5


def tensor_bytes(name, dims, data):
    out = struct.pack("<I", len(name)) + name.encode()
    out += struct.pack("<BB", 0, len(dims))  # dtype 0 = f32
    for d in dims:
        out += struct.pack("<I", d)
    assert len(data) == prod(dims), (name, dims, len(data))
    for v in data:
        out += struct.pack("<f", v)
    return out


def prod(dims):
    p = 1
    for d in dims:
        p *= d
    return p


def bundle_bytes(tensors):
    out = b"CPT1" + struct.pack("<I", len(tensors))
    for name, dims, data in tensors:
        out += tensor_bytes(name, dims, data)
    return out


def layer(kind, cin=0, cout=0, k=0, pool=2, arch="circ", l=4, act="4.0"):
    return (
        '{"kind": "%s", "cin": %d, "cout": %d, "k": %d, "pool": %d, '
        '"arch": "%s", "l": %d, "act_scale": %s}'
        % (kind, cin, cout, k, pool, arch, l, act)
    )


def manifest_json(bn_cin=4, fc_act="4.0"):
    layers = ",\n    ".join(
        [
            layer("conv", cin=1, cout=4, k=3),
            layer("bn", cin=bn_cin, cout=bn_cin),
            layer("relu"),
            layer("pool"),
            layer("flatten"),
            layer("fc", cin=64, cout=3, act=fc_act),
        ]
    )
    return (
        '{\n  "dataset": "mnist",\n  "classes": 3,\n  "layers": [\n    %s\n  ]\n}\n'
        % layers
    )


def write(name, data):
    mode = "wb" if isinstance(data, bytes) else "w"
    with open(os.path.join(HERE, name), mode) as f:
        f.write(data)
    print("wrote", name)


def fill(dims, salt):
    return [val(salt + i) for i in range(prod(dims))]


VALID_TENSORS = [
    ("layer0.w", [1, 3, 4], fill([1, 3, 4], 0)),
    ("layer0.b", [4], fill([4], 100)),
    ("layer1.gamma", [4], [1.0 + 0.1 * i for i in range(4)]),
    ("layer1.beta", [4], fill([4], 200)),
    ("layer1.state.mean", [4], fill([4], 300)),
    ("layer1.state.var", [4], [0.5 + 0.25 * i for i in range(4)]),
    ("layer5.w", [1, 16, 4], fill([1, 16, 4], 400)),
    ("layer5.b", [3], fill([3], 500)),
]


def variant(replace=None, extra=None):
    out = []
    for name, dims, data in VALID_TENSORS:
        if replace and name in replace:
            dims = replace[name]
            data = fill(dims, 900)
        out.append((name, dims, data))
    if extra:
        out.extend(extra)
    return out


CHIP_JSON = """{
  "l": 4,
  "gamma_true": [1.0, 0.02, 0.02, 0.02,
                 0.02, 1.0, 0.02, 0.02,
                 0.02, 0.02, 1.0, 0.02,
                 0.02, 0.02, 0.02, 1.0],
  "resp": [1.0, 1.0, 1.0, 1.0],
  "dark": 0.0,
  "sigma_rel": 0.01,
  "sigma_abs": 0.001,
  "w_bits": 8,
  "x_bits": 8,
  "seed": 7
}
"""

# same chip, but an MRR bank of 8 resident tiles: smaller than layer5's
# single block-row of Q=16 tiles, so no farm width can serve the model
# (block-rows are the partition planner's unit of assignment)
TINY_MRR_JSON = CHIP_JSON.replace(
    '"seed": 7', '"seed": 7,\n  "mrr_capacity": 8'
)

write("valid_model.json", manifest_json())
write("valid_model.cpt", bundle_bytes(VALID_TENSORS))
write("chip.json", CHIP_JSON)
write("chip_tiny_mrr.json", TINY_MRR_JSON)

write("corrupt_graph.json", manifest_json(bn_cin=8))
write("corrupt_quant.json", manifest_json(fc_act="1e999"))
write("corrupt_blocks.cpt", bundle_bytes(variant(replace={"layer5.w": [1, 13, 5]})))
write(
    "corrupt_dangling.cpt",
    bundle_bytes(variant(extra=[("layer9.w", [1, 1, 4], fill([1, 1, 4], 800))])),
)
write("corrupt_spectra.cpt", bundle_bytes(variant(replace={"layer5.w": [1, 16, 8]})))
