//! Planned-vs-unplanned bit-identity: the contract of the planned
//! execution path (DESIGN.md §perf).
//!
//! The planned path (cached FFT plans + weight spectra, pre-encoded chip
//! tiles, scratch arenas, scoped threads) must produce **exactly** the
//! bytes of the unplanned reference — across random BCM shapes, through
//! the whole engine on both backends, across drift ticks that invalidate
//! the encoded-tile cache, and across an `EngineSlot` hot swap that
//! retires one engine's tiles for another's.

use std::sync::mpsc;
use std::sync::Arc;

use cirptc::circulant::{fft, Bcm, SignSplit};
use cirptc::coordinator::Metrics;
use cirptc::data::Bundle;
use cirptc::drift::{
    DriftBackend, DriftConfig, DriftModel, DriftMonitor, DriftShared,
    EngineSlot, MonitorConfig,
};
use cirptc::onn::{Backend, Engine, Manifest};
use cirptc::simulator::{ChipDescription, ChipSim};
use cirptc::tensor::Tensor;
use cirptc::util::propcheck;
use cirptc::util::rng::Rng;

/// A mildly non-ideal chip of block order `l` (quantizers, Γ mixing,
/// responsivity tilt, dark) — every encode stage exercised, no noise.
fn chip(l: usize) -> ChipDescription {
    let mut d = ChipDescription::ideal(l);
    for i in 0..l {
        for j in 0..l {
            if i != j {
                d.gamma[i * l + j] = 0.02 / (1.0 + (i as f32 - j as f32).abs());
            }
        }
        d.resp[i] = 1.0 - 0.02 * i as f32;
    }
    d.w_bits = 6;
    d.x_bits = 4;
    d.dark = 0.01;
    d
}

#[test]
fn planned_mmm_fft_bit_identical_over_random_shapes() {
    // the full (P, Q, l, b) lattice, including the serving order l=64
    propcheck::check("planned fft path == reference", 30, |g| {
        let (p, q) = (g.usize_in(1, 5), g.usize_in(1, 5));
        let l = *g.choose(&[2usize, 4, 8, 16, 32, 64]);
        let b = g.usize_in(1, 9);
        let bcm = {
            let mut w = vec![0.0f32; p * q * l];
            g.rng.fill_uniform(&mut w);
            Bcm::new(p, q, l, w)
        };
        let x = Tensor::new(&[bcm.n(), b], g.vec_f32(bcm.n() * b, -1.0, 1.0));
        let dy = Tensor::new(&[bcm.m(), b], g.vec_f32(bcm.m() * b, -1.0, 1.0));
        let plan = fft::plan_for(l);
        let spec = fft::WeightSpectra::new(&bcm, &plan);
        let want = bcm.mmm_fft(&x);
        let (dw_r, dx_r) = bcm.mmm_fft_backward(&x, &dy);
        for threads in [1usize, 3, 8] {
            let got = fft::bcm_mmm_fft_planned(&bcm, &x, &plan, &spec, threads);
            cirptc::prop_assert!(
                got.data == want.data,
                "forward diverged: p={p} q={q} l={l} b={b} threads={threads}"
            );
            let (dw_p, dx_p) = fft::bcm_mmm_fft_backward_planned(
                &bcm, &x, &dy, &plan, &spec, threads,
            );
            cirptc::prop_assert!(
                dw_p == dw_r && dx_p.data == dx_r.data,
                "backward diverged: p={p} q={q} l={l} b={b} threads={threads}"
            );
        }
        Ok(())
    });
}

#[test]
fn planned_chip_passes_bit_identical_over_random_shapes() {
    propcheck::check("planned chip pass == reference", 25, |g| {
        let (p, q) = (g.usize_in(1, 4), g.usize_in(1, 4));
        let l = *g.choose(&[2usize, 4, 8]);
        let b = g.usize_in(1, 6);
        let bcm = {
            let mut w = vec![0.0f32; p * q * l];
            g.rng.fill_uniform(&mut w);
            for v in w.iter_mut() {
                *v -= 0.5;
            }
            Bcm::new(p, q, l, w)
        };
        let sign = SignSplit::of(&bcm);
        let x = Tensor::new(&[bcm.n(), b], g.vec_f32(bcm.n() * b, 0.0, 1.0));
        let mut plain = ChipSim::deterministic(chip(l));
        let mut planned = ChipSim::deterministic(chip(l));
        for _ in 0..3 {
            let y0 = plain.forward_signed(&bcm, &x);
            let y1 = planned.forward_signed_planned(1, 0, &sign, &x);
            cirptc::prop_assert!(
                y0.data == y1.data,
                "chip pass diverged: p={p} q={q} l={l} b={b}"
            );
        }
        Ok(())
    });
}

/// In-memory circ engine: conv(1→cout, k=3) → relu → flatten → fc → 3
/// classes, on 8×8 inputs, all layers at block order `l`.
fn build_engine(l: usize, cout: usize, seed: u64) -> Engine {
    let n_fc = cout * 64;
    let manifest = Manifest::parse(&format!(
        r#"{{
          "dataset": "planned_prop", "classes": 3,
          "layers": [
            {{"kind": "conv", "cin": 1, "cout": {cout}, "k": 3, "pool": 2,
             "arch": "circ", "l": {l}, "act_scale": 4.0}},
            {{"kind": "relu", "cin": 0, "cout": 0, "k": 3, "pool": 2,
             "arch": "circ", "l": {l}, "act_scale": 4.0}},
            {{"kind": "flatten", "cin": 0, "cout": 0, "k": 3, "pool": 2,
             "arch": "circ", "l": {l}, "act_scale": 4.0}},
            {{"kind": "fc", "cin": {n_fc}, "cout": 3, "k": 3, "pool": 2,
             "arch": "circ", "l": {l}, "act_scale": 4.0}}
          ]}}"#
    ))
    .unwrap();
    let mut bundle = Bundle::default();
    let mut rng = Rng::new(seed);
    let specs = manifest.layers.clone();
    for (i, spec) in specs.iter().enumerate() {
        if !matches!(spec.kind.as_str(), "conv" | "fc") {
            continue;
        }
        let (p, q) = spec.bcm_dims();
        let mut w = vec![0.0f32; p * q * spec.l];
        rng.fill_uniform(&mut w);
        for v in w.iter_mut() {
            *v = (*v - 0.5) * 0.4;
        }
        bundle.insert_f32(&format!("layer{i}.w"), &[p, q, spec.l], w);
        let mut bias = vec![0.0f32; spec.cout];
        rng.fill_uniform(&mut bias);
        bundle.insert_f32(&format!("layer{i}.b"), &[spec.cout], bias);
    }
    Engine::from_parts(manifest, &bundle).unwrap()
}

fn images(n: usize, seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut d = vec![0.0f32; 64];
            rng.fill_uniform(&mut d);
            Tensor::new(&[1, 8, 8], d)
        })
        .collect()
}

#[test]
fn planned_engine_bit_identical_over_shapes_and_backends() {
    propcheck::check("planned engine == reference engine", 8, |g| {
        let l = *g.choose(&[2usize, 4, 16]);
        let cout = *g.choose(&[4usize, 8]);
        let b = g.usize_in(1, 5);
        let seed = g.usize_in(1, 1_000_000) as u64;
        let planned = build_engine(l, cout, seed);
        let mut reference = build_engine(l, cout, seed);
        reference.use_plans = false;
        let imgs = images(b, seed ^ 0xABCD);
        let yd_p = planned
            .forward_batch(&imgs, &mut Backend::Digital)
            .unwrap();
        let yd_r = reference
            .forward_batch(&imgs, &mut Backend::Digital)
            .unwrap();
        cirptc::prop_assert!(yd_p == yd_r, "digital diverged: l={l} b={b}");
        let mut be_p =
            Backend::PhotonicSim(ChipSim::deterministic(chip(l)));
        let mut be_r =
            Backend::PhotonicSim(ChipSim::deterministic(chip(l)));
        let yp = planned.forward_batch(&imgs, &mut be_p).unwrap();
        let yr = reference.forward_batch(&imgs, &mut be_r).unwrap();
        cirptc::prop_assert!(yp == yr, "photonic diverged: l={l} b={b}");
        Ok(())
    });
}

fn accel_drift(seed: u64) -> DriftConfig {
    DriftConfig {
        seed,
        passes_per_tick: 3,
        gamma_walk: 2e-3,
        resp_tilt: 5e-3,
        dark_creep: 1e-4,
        max_ticks: 0,
    }
}

#[test]
fn planned_engine_survives_drift_invalidation_bit_identically() {
    // a stale encoded tile would be a *silent* accuracy bug: the serving
    // path keeps producing plausible numbers computed against the chip's
    // old responsivity.  Running identical drift episodes through the
    // planned and reference engines catches any missed invalidation as a
    // byte-level divergence on the first post-tick batch.
    let planned = build_engine(4, 8, 77);
    let mut reference = build_engine(4, 8, 77);
    reference.use_plans = false;
    let run = |engine: &Engine| -> Vec<Vec<Vec<f32>>> {
        let mut sim = ChipSim::deterministic(chip(4));
        sim.set_drift(DriftModel::new(accel_drift(5)));
        let mut be = Backend::PhotonicSim(sim);
        (0..8u64)
            .map(|i| {
                engine
                    .forward_batch(&images(3, 1000 + i), &mut be)
                    .unwrap()
            })
            .collect()
    };
    assert_eq!(run(&planned), run(&reference));
}

#[test]
fn drift_tick_forces_reencode_of_cached_tiles() {
    // the direct observable behind the bit-identity above: after the
    // first on_pass tick, the planned path must re-encode instead of
    // serving the pre-drift tiles
    let engine = build_engine(4, 8, 78);
    let mut sim = ChipSim::deterministic(chip(4));
    // passes_per_tick=5: the first batch (4 passes: 2 layers × 2 sign
    // halves) finishes before any tick, so its 4 encodes are the
    // steady-state set; the tick lands during batch 2
    sim.set_drift(DriftModel::new(DriftConfig {
        seed: 9,
        passes_per_tick: 5,
        gamma_walk: 1e-3,
        resp_tilt: 4e-3,
        dark_creep: 1e-4,
        max_ticks: 0,
    }));
    let mut be = Backend::PhotonicSim(sim);
    engine
        .forward_batch(&images(2, 2000), &mut be)
        .unwrap();
    if let Backend::PhotonicSim(sim) = &be {
        assert_eq!(sim.encodes_done, 4, "first batch encodes each tile once");
    }
    engine
        .forward_batch(&images(2, 2001), &mut be)
        .unwrap();
    if let Backend::PhotonicSim(sim) = &be {
        assert!(
            sim.encodes_done > 4,
            "the drift tick must invalidate the encoded-tile cache \
             (encodes_done = {})",
            sim.encodes_done
        );
    }
}

#[test]
fn hot_swap_retires_the_old_engines_tiles_bit_identically() {
    // one worker chip serves engine A, then a hot-swapped engine B with
    // different weights through the *same* sim.  B's outputs must match a
    // reference-mode B on a fresh chip — i.e. no tile encoded for A (or
    // cached by A's owner id) leaks into B's passes.
    let slot = EngineSlot::new(build_engine(4, 8, 101));
    let imgs = images(3, 3000);
    let mut be = Backend::PhotonicSim(ChipSim::deterministic(chip(4)));
    let a_first = slot.current().forward_batch(&imgs, &mut be).unwrap();
    slot.current().forward_batch(&imgs, &mut be).unwrap();
    // zero-downtime swap: readers pick up B between batches
    slot.swap(build_engine(4, 8, 202));
    let b_served = slot.current().forward_batch(&imgs, &mut be).unwrap();
    let mut reference = build_engine(4, 8, 202);
    reference.use_plans = false;
    let mut be_ref = Backend::PhotonicSim(ChipSim::deterministic(chip(4)));
    let b_want = reference.forward_batch(&imgs, &mut be_ref).unwrap();
    assert_eq!(b_served, b_want, "swapped-in engine must re-encode");
    assert_ne!(a_first, b_served, "distinct weights must serve distinctly");
    if let Backend::PhotonicSim(sim) = &be {
        assert_eq!(
            sim.encodes_done, 8,
            "4 tiles for engine A + 4 re-encoded for engine B"
        );
    }
}

#[test]
fn drift_backend_probe_cache_stays_correct_under_monitoring() {
    // the monitor's probe tile rides the same encode cache; interleaving
    // probes with serving batches must leave both bit-identical to the
    // unplanned world (metrics equal, no cross-contamination)
    let run = |use_plans: bool| -> (Vec<Vec<Vec<f32>>>, i64) {
        let metrics = Arc::new(Metrics::default());
        let mut engine = build_engine(4, 8, 55);
        engine.use_plans = use_plans;
        let shared = DriftShared::new(engine, Arc::clone(&metrics));
        let desc = chip(4);
        let mut sim = ChipSim::deterministic(desc.clone());
        sim.set_drift(DriftModel::new(accel_drift(3)));
        let monitor = DriftMonitor::new(
            MonitorConfig {
                probe_every: 1,
                residual_trigger: f32::INFINITY,
                cooldown_passes: 0,
                ..MonitorConfig::default()
            },
            &desc,
        );
        let (tx, rx) = mpsc::channel();
        drop(rx); // monitor-only
        let mut be = DriftBackend::new(shared, sim, monitor, tx);
        use cirptc::coordinator::InferenceBackend;
        let out = (0..6u64)
            .map(|i| be.infer_batch(&images(2, 4000 + i)).unwrap())
            .collect();
        (out, metrics.last_probe_residual_ppm.get())
    };
    let (planned, res_p) = run(true);
    let (reference, res_r) = run(false);
    assert_eq!(planned, reference, "monitored serving must be bit-identical");
    assert_eq!(res_p, res_r, "probe residuals must agree exactly");
}
