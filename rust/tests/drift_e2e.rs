//! End-to-end drift subsystem (ISSUE 4 acceptance path): a served model
//! on a chip under a seeded Γ/responsivity/dark walk.
//!
//! * **unmitigated** — the drifting chip measurably degrades serving
//!   accuracy once the walk plateaus;
//! * **mitigated** — the same walk with the drift monitor + background
//!   recalibrator recovers to within 2 pp of the pre-drift baseline,
//!   with zero dropped or failed requests while engines hot-swap under
//!   live traffic.
//!
//! Everything is seeded: the drift walk, the probe tile, the fine-tune
//! shuffles and the synthetic data.  The only nondeterminism is *when*
//! (in wall time) a background recalibration lands — the test
//! synchronizes on the shared metrics, never on sleeps alone.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use cirptc::coordinator::{
    BackendFactory, BatcherConfig, Coordinator, InferenceBackend, Metrics,
    StagedFactory,
};
use cirptc::data::datasets::{self, SHAPES_MANIFEST_JSON, Split};
use cirptc::drift::{
    staged_drift, DriftBackend, DriftConfig, DriftModel, DriftMonitor,
    DriftShared, MonitorConfig, RecalConfig, Recalibrator, RecalRequest,
};
use cirptc::onn::{Backend, Engine, Manifest};
use cirptc::simulator::{ChipDescription, ChipSim};
use cirptc::tensor::{argmax, Tensor};
use cirptc::train::{
    fit, gather_batch, Optimizer, TrainBackend, TrainConfig, TrainModel,
};

/// The as-calibrated deployment chip: quantizers on, deterministic.
fn chip0() -> ChipDescription {
    let mut d = ChipDescription::ideal(4);
    d.w_bits = 6;
    d.x_bits = 4;
    d.dark = 0.01;
    d.seed = 11;
    d
}

/// Accelerated drift episode: tick every pass, plateau after 120 ticks.
fn drift_cfg() -> DriftConfig {
    DriftConfig {
        seed: 0xD5,
        passes_per_tick: 1,
        gamma_walk: 1.5e-3,
        resp_tilt: 3e-3,
        dark_creep: 2e-4,
        max_ticks: 120,
    }
}

const PLATEAU_TICKS: i64 = 120;
/// chunk size = max_batch: each chunk drains as (usually) one batch
const CHUNK: usize = 8;

/// Train the model digitally, then BN-calibrate it on the deployment
/// chip (the paper's one-shot calibration at the calibration point).
fn trained_model(manifest: &Manifest, train_split: &Split) -> TrainModel {
    let mut model = TrainModel::init(manifest.clone(), 0xA4).unwrap();
    let mut backend = TrainBackend::Digital;
    let mut opt = Optimizer::adam(5e-3);
    let cfg = TrainConfig { epochs: 8, batch: 16, max_steps: 0, seed: 0xA5 };
    let hist = fit(&mut model, &mut backend, &mut opt, train_split, &cfg)
        .unwrap();
    assert!(
        hist.last().unwrap() < hist.first().unwrap(),
        "training must converge: {hist:?}"
    );
    let batches: Vec<Tensor> = (0..6)
        .map(|i| {
            let idx: Vec<usize> = (i * 16..(i + 1) * 16).collect();
            gather_batch(train_split, &idx).0
        })
        .collect();
    let mut chip_backend = TrainBackend::Chip(ChipSim::deterministic(chip0()));
    model.recalibrate_bn(&batches, &mut chip_backend).unwrap();
    model
}

/// Accuracy of `engine` over `eval` through a (static) chip at `desc`,
/// in the same chunks-of-8 the coordinator phases use.
fn chip_eval_accuracy(engine: &Engine, eval: &Split, desc: ChipDescription) -> f64 {
    let mut be = Backend::PhotonicSim(ChipSim::deterministic(desc));
    let mut correct = 0usize;
    let mut s = 0usize;
    while s < eval.n {
        let e = (s + CHUNK).min(eval.n);
        let imgs: Vec<Tensor> = (s..e).map(|i| eval.image(i)).collect();
        let logits = engine.forward_batch(&imgs, &mut be).unwrap();
        for (row, i) in logits.iter().zip(s..e) {
            if argmax(row) == eval.labels[i] as usize {
                correct += 1;
            }
        }
        s = e;
    }
    correct as f64 / eval.n as f64
}

/// One pass of the eval set through the live coordinator; panics on any
/// dropped request (a dropped reply channel fails the `wait`).
fn serve_round(coord: &Coordinator, eval: &Split) -> f64 {
    let mut correct = 0usize;
    let mut s = 0usize;
    while s < eval.n {
        let e = (s + CHUNK).min(eval.n);
        let imgs: Vec<Tensor> = (s..e).map(|i| eval.image(i)).collect();
        let responses = coord.classify_all(&imgs).unwrap();
        assert_eq!(responses.len(), imgs.len(), "request dropped");
        for (r, i) in responses.iter().zip(s..e) {
            if argmax(&r.logits) == eval.labels[i] as usize {
                correct += 1;
            }
        }
        s = e;
    }
    correct as f64 / eval.n as f64
}

/// One drift-monitored photonic worker over a fresh chip at the
/// calibration point, with the episode's drift process attached.
fn drift_factory(
    shared: &Arc<DriftShared>,
    tx: mpsc::Sender<RecalRequest>,
    mcfg: MonitorConfig,
) -> BackendFactory {
    let shared = Arc::clone(shared);
    Box::new(move || {
        let desc = chip0();
        let mut sim = ChipSim::deterministic(desc.clone());
        sim.set_drift(DriftModel::new(drift_cfg()));
        let monitor = DriftMonitor::new(mcfg, &desc);
        Box::new(DriftBackend::new(shared, sim, monitor, tx))
            as Box<dyn InferenceBackend>
    })
}

fn batcher() -> BatcherConfig {
    BatcherConfig { max_batch: CHUNK, max_wait_us: 20_000, queue_cap: 0 }
}

#[test]
fn pipelined_drift_serving_probes_and_drops_nothing() {
    // the *pipelined* coordinator under a drifting, monitored chip: the
    // monitor rides the chip-stage hook, probe passes interleave with
    // traffic exactly as in the sequential DriftBackend, and no request
    // is dropped or failed while the chip walks — the stage split's
    // zero-drop guarantee under drift.  (Full recalibration recovery is
    // pinned sequentially below; hot swaps through the pipeline are
    // pinned bit-identically in rust/tests/pipelined_path.rs.)
    let manifest = Manifest::parse(SHAPES_MANIFEST_JSON).unwrap();
    let eval_split = datasets::synth_shapes(64, 0xB3);
    // accuracy is not under test here, so an untrained model keeps this
    // variant cheap; the drift/probe/accounting machinery is identical
    let model = TrainModel::init(manifest.clone(), 0xB4).unwrap();
    let bundle = model.export_bundle();
    let metrics = Arc::new(Metrics::default());
    let engine = Engine::from_parts(manifest, &bundle).unwrap();
    let shared = DriftShared::new(engine, Arc::clone(&metrics));
    let (tx, rx) = mpsc::channel();
    drop(rx); // monitor-only: probes + metrics, no recalibrator
    let mcfg = MonitorConfig {
        probe_every: 1,
        residual_trigger: f32::INFINITY,
        cooldown_passes: 0,
        ..MonitorConfig::default()
    };
    let staged: StagedFactory = {
        let shared = Arc::clone(&shared);
        Box::new(move || {
            let desc = chip0();
            let mut sim = ChipSim::deterministic(desc.clone());
            sim.set_drift(DriftModel::new(drift_cfg()));
            let monitor = DriftMonitor::new(mcfg, &desc);
            staged_drift(shared, sim, monitor, tx)
        })
    };
    let coord = Coordinator::start_pipelined_with_metrics(
        vec![staged],
        // admission control armed, but bounded well above the in-flight
        // ceiling: the zero-drop claim covers every accepted request
        BatcherConfig {
            max_batch: CHUNK,
            max_wait_us: 20_000,
            queue_cap: 1024,
        },
        Arc::clone(&metrics),
    );
    let mut rounds = 0;
    while metrics.drift_ticks.get() < PLATEAU_TICKS {
        serve_round(&coord, &eval_split);
        rounds += 1;
        assert!(rounds <= 24, "drift clock must reach the plateau");
    }
    assert_eq!(metrics.errors.get(), 0, "no request may fail");
    assert_eq!(metrics.rejected.get(), 0, "nothing sheds below the cap");
    assert_eq!(
        metrics.completed.get(),
        metrics.submitted.get(),
        "every accepted request must complete"
    );
    assert!(metrics.probes.get() > 0, "probes must interleave with traffic");
    assert!(
        metrics.last_probe_residual_ppm.get() > 0,
        "the walking chip must show a residual: {}",
        metrics.summary()
    );
    // all three lanes ran and were timed
    assert!(metrics.stage_pre_us.count() > 0);
    assert_eq!(metrics.stage_chip_us.count(), metrics.stage_pre_us.count());
    assert_eq!(metrics.stage_post_us.count(), metrics.stage_pre_us.count());
    drop(coord);
}

#[test]
fn drift_degrades_and_recalibration_recovers_without_drops() {
    let manifest = Manifest::parse(SHAPES_MANIFEST_JSON).unwrap();
    let train_split = datasets::synth_shapes(192, 0xA1);
    let calib_split = datasets::synth_shapes(128, 0xA2);
    let eval_split = datasets::synth_shapes(128, 0xA3);
    let model = trained_model(&manifest, &train_split);
    let bundle = model.export_bundle();

    // -- pre-drift baseline (engine + chip at the calibration point) ---
    let engine = Engine::from_parts(manifest.clone(), &bundle).unwrap();
    let acc_base = chip_eval_accuracy(&engine, &eval_split, chip0());
    println!("baseline accuracy at the calibration point: {acc_base:.4}");
    assert!(acc_base > 0.5, "model must serve well pre-drift: {acc_base}");

    // -- phase B: unmitigated drift ------------------------------------
    let acc_drifted = {
        let metrics = Arc::new(Metrics::default());
        let engine = Engine::from_parts(manifest.clone(), &bundle).unwrap();
        let shared = DriftShared::new(engine, Arc::clone(&metrics));
        let (tx, rx) = mpsc::channel();
        drop(rx); // monitor-only: probes + metrics, no recalibrator
        let mcfg = MonitorConfig {
            probe_every: 1,
            residual_trigger: f32::INFINITY,
            cooldown_passes: 0,
            ..MonitorConfig::default()
        };
        let coord = Coordinator::start_with_metrics(
            vec![drift_factory(&shared, tx, mcfg)],
            batcher(),
            Arc::clone(&metrics),
        );
        // drive the pass clock until the walk plateaus, then measure
        let mut rounds = 0;
        while metrics.drift_ticks.get() < PLATEAU_TICKS {
            serve_round(&coord, &eval_split);
            rounds += 1;
            assert!(rounds <= 12, "drift clock must reach the plateau");
        }
        let acc = serve_round(&coord, &eval_split);
        assert_eq!(metrics.errors.get(), 0);
        assert!(metrics.probes.get() > 0, "probes must run");
        assert!(
            metrics.last_probe_residual_ppm.get() > 10_000,
            "plateaued drift must show a large probe residual: {}",
            metrics.summary()
        );
        println!("unmitigated accuracy at the plateau: {acc:.4}");
        acc
    };
    assert!(
        acc_base - acc_drifted >= 0.04,
        "drift must degrade serving measurably: base {acc_base:.4} vs \
         drifted {acc_drifted:.4}"
    );

    // -- phase C: monitored + recalibrating coordinator ----------------
    let snapdir = std::env::temp_dir().join("cirptc_drift_e2e_snapshots");
    let _ = std::fs::remove_dir_all(&snapdir);
    let metrics = Arc::new(Metrics::default());
    let engine = Engine::from_parts(manifest.clone(), &bundle).unwrap();
    let shared = DriftShared::new(engine, Arc::clone(&metrics));
    let (tx, rx) = mpsc::channel();
    let rcfg = RecalConfig {
        fine_tune_steps: 48,
        lr: 2e-3,
        batch: 16,
        bn_batches: 6,
        seed: 0xC1,
        noisy: false,
        snapshot_dir: Some(snapdir.clone()),
    };
    let _recal = Recalibrator::new(
        model.clone(),
        calib_split,
        rcfg,
        Arc::clone(&shared),
    )
    .spawn(rx);
    let mcfg = MonitorConfig {
        probe_every: 1,
        residual_trigger: 0.04,
        cooldown_passes: 40,
        ..MonitorConfig::default()
    };
    let coord = Coordinator::start_with_metrics(
        vec![drift_factory(&shared, tx, mcfg)],
        batcher(),
        Arc::clone(&metrics),
    );

    // drive to the plateau under live traffic (recalibrations may already
    // be landing in the background — requests keep flowing throughout)
    let mut rounds = 0;
    while metrics.drift_ticks.get() < PLATEAU_TICKS {
        serve_round(&coord, &eval_split);
        rounds += 1;
        assert!(rounds <= 12, "drift clock must reach the plateau");
    }
    // settle: keep serving until a recalibration has landed *and* the
    // probe residual (drift since that recalibration's operating point)
    // is back under the trigger — i.e. the served weights match the
    // plateaued chip
    let deadline = Instant::now() + Duration::from_secs(300);
    loop {
        serve_round(&coord, &eval_split);
        let settled = metrics.recalibrations.get() >= 1
            && metrics.last_probe_residual_ppm.get() < 40_000;
        if settled {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "recalibration never settled: {}",
            metrics.summary()
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    let acc_recal = serve_round(&coord, &eval_split);
    println!(
        "recalibrated accuracy at the plateau: {acc_recal:.4} \
         ({} recalibrations)",
        metrics.recalibrations.get()
    );
    println!("metrics: {}", metrics.summary());

    // recovery: within 2 pp of the pre-drift baseline
    assert!(
        acc_recal >= acc_base - 0.02,
        "recalibration must recover to within 2 pp: base {acc_base:.4} \
         vs recalibrated {acc_recal:.4}"
    );
    // zero-downtime: every submitted request completed, none failed
    assert_eq!(metrics.errors.get(), 0, "no request may fail");
    assert_eq!(
        metrics.completed.get(),
        metrics.submitted.get(),
        "every request must complete"
    );
    assert!(metrics.recalibrations.get() >= 1, "a hot swap must land");
    assert!(metrics.probes.get() > 0);

    // the drifted-chip snapshot is attributable: it reloads through the
    // path-carrying ChipDescription::load
    let snap0 = snapdir.join("drift_snapshot_0.json");
    assert!(snap0.exists(), "recalibration must snapshot the chip");
    let snap = ChipDescription::load(&snap0).unwrap();
    assert_eq!(snap.l, 4);
    assert_ne!(snap.resp, vec![1.0; 4], "snapshot must capture the drift");

    drop(coord);
}
