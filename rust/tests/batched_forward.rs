//! Property tests for the batch-major engine: for random manifests and
//! batch sizes, the batched digital and batched deterministic-photonic
//! forwards must be element-wise identical to the per-image loop,
//! including the batch=1 and ragged-final-batch edges, and the chip's
//! pass/tile accounting must stay meaningful under batching (one
//! sign-split pass pair per linear layer per batch, tiles scaling with
//! the streamed columns).

use cirptc::data::Bundle;
use cirptc::onn::{Backend, Engine, Manifest};
use cirptc::prop_assert;
use cirptc::simulator::{ChipDescription, ChipSim};
use cirptc::tensor::Tensor;
use cirptc::util::propcheck::{self, Gen, PropResult};

const L: usize = 4;

fn ceil_to(x: usize, m: usize) -> usize {
    x.div_ceil(m) * m
}

/// A random small circ model: conv → bn → relu → pool → flatten → fc.
/// Returns the engine plus the (cin, h) input geometry and the per-layer
/// (P, Q) block counts of the two linear layers (for tile accounting).
fn random_engine(g: &mut Gen) -> (Engine, usize, usize, [(usize, usize); 2]) {
    let cin = g.usize_in(1, 2);
    let cout = *g.choose(&[4usize, 8]);
    let h = *g.choose(&[4usize, 6, 8]);
    let classes = g.usize_in(2, 5);
    let fc_in = cout * (h / 2) * (h / 2);
    let layer = |kind: &str, cin: usize, cout: usize| {
        format!(
            r#"{{"kind": "{kind}", "cin": {cin}, "cout": {cout}, "k": 3,
                 "pool": 2, "arch": "circ", "l": {L}, "act_scale": 4.0}}"#
        )
    };
    let manifest = Manifest::parse(&format!(
        r#"{{"dataset": "synth", "classes": {classes}, "layers": [
            {}, {}, {}, {}, {}, {}
        ]}}"#,
        layer("conv", cin, cout),
        layer("bn", cout, 0),
        layer("relu", 0, 0),
        layer("pool", 0, 0),
        layer("flatten", 0, 0),
        layer("fc", fc_in, classes),
    ))
    .expect("manifest parses");

    let n_in = cin * 9;
    let (p0, q0) = (ceil_to(cout, L) / L, ceil_to(n_in, L) / L);
    let (p5, q5) = (ceil_to(classes, L) / L, ceil_to(fc_in, L) / L);

    let mut bundle = Bundle::default();
    let centered = |g: &mut Gen, n: usize, scale: f32| -> Vec<f32> {
        g.vec_f32(n, -scale, scale)
    };
    let w0 = centered(g, p0 * q0 * L, 0.4);
    bundle.insert_f32("layer0.w", &[p0, q0, L], w0);
    bundle.insert_f32("layer0.b", &[cout], centered(g, cout, 0.1));
    bundle.insert_f32("layer1.gamma", &[cout], g.vec_f32(cout, 0.5, 1.5));
    bundle.insert_f32("layer1.beta", &[cout], centered(g, cout, 0.2));
    bundle.insert_f32("layer1.state.mean", &[cout], centered(g, cout, 0.2));
    bundle.insert_f32("layer1.state.var", &[cout], g.vec_f32(cout, 0.5, 2.0));
    let w5 = centered(g, p5 * q5 * L, 0.2);
    bundle.insert_f32("layer5.w", &[p5, q5, L], w5);
    bundle.insert_f32("layer5.b", &[classes], centered(g, classes, 0.1));

    let engine = Engine::from_parts(manifest, &bundle).expect("engine builds");
    (engine, cin, h, [(p0, q0), (p5, q5)])
}

fn random_images(g: &mut Gen, b: usize, cin: usize, h: usize) -> Vec<Tensor> {
    (0..b)
        .map(|_| Tensor::new(&[cin, h, h], g.vec_f32(cin * h * h, 0.0, 1.0)))
        .collect()
}

fn chip_desc() -> ChipDescription {
    let mut d = ChipDescription::ideal(L);
    d.w_bits = 6;
    d.x_bits = 4;
    d.dark = 0.015;
    d
}

fn rows_equal(a: &[Vec<f32>], b: &[Vec<f32>], what: &str) -> PropResult {
    prop_assert!(a.len() == b.len(), "{what}: {} vs {} rows", a.len(), b.len());
    for (i, (ra, rb)) in a.iter().zip(b).enumerate() {
        prop_assert!(
            ra == rb,
            "{what}: row {i} differs: {ra:?} vs {rb:?}"
        );
    }
    Ok(())
}

#[test]
fn batched_forward_identical_to_per_image_loop() {
    propcheck::check("batched == per-image (digital + photonic)", 25, |g| {
        let (engine, cin, h, _) = random_engine(g);
        // batch sizes covering the b=1 edge and odd widths
        let b = if g.bool() { 1 } else { g.usize_in(2, 7) };
        let images = random_images(g, b, cin, h);

        // digital
        let batched = engine
            .forward_batch(&images, &mut Backend::Digital)
            .expect("digital batch");
        let looped: Vec<Vec<f32>> = images
            .iter()
            .map(|im| engine.forward(im, &mut Backend::Digital).unwrap())
            .collect();
        rows_equal(&batched, &looped, "digital")?;

        // deterministic photonic: fresh chip per run so state can't leak
        let mut be_batch =
            Backend::PhotonicSim(ChipSim::deterministic(chip_desc()));
        let batched = engine
            .forward_batch(&images, &mut be_batch)
            .expect("photonic batch");
        let looped: Vec<Vec<f32>> = images
            .iter()
            .map(|im| {
                let mut be =
                    Backend::PhotonicSim(ChipSim::deterministic(chip_desc()));
                engine.forward(im, &mut be).unwrap()
            })
            .collect();
        rows_equal(&batched, &looped, "photonic")?;
        Ok(())
    });
}

#[test]
fn ragged_final_batch_matches_full_batch() {
    propcheck::check("chunked serving batches == one batch", 15, |g| {
        let (engine, cin, h, _) = random_engine(g);
        let n = g.usize_in(3, 9);
        let max_batch = g.usize_in(2, n.max(3) - 1);
        let images = random_images(g, n, cin, h);
        let full = engine
            .forward_batch(&images, &mut Backend::Digital)
            .expect("full batch");
        // the worker-loop shape: full chunks then a ragged tail
        let mut chunked = Vec::new();
        for chunk in images.chunks(max_batch) {
            chunked.extend(
                engine
                    .forward_batch(chunk, &mut Backend::Digital)
                    .expect("chunk"),
            );
        }
        rows_equal(&chunked, &full, "ragged chunking")?;
        Ok(())
    });
}

#[test]
fn batched_pass_and_tile_accounting() {
    propcheck::check("passes flat per layer, tiles scale with cols", 15, |g| {
        let (engine, cin, h, blocks) = random_engine(g);
        let b = g.usize_in(1, 6);
        let images = random_images(g, b, cin, h);
        let mut be = Backend::PhotonicSim(ChipSim::deterministic(chip_desc()));
        engine.forward_batch(&images, &mut be).unwrap();
        let Backend::PhotonicSim(sim) = &be else { unreachable!() };
        // one sign-split pass pair per linear layer, regardless of b
        prop_assert!(
            sim.passes() == 4,
            "expected 4 passes (2 linear layers × sign split), got {}",
            sim.passes()
        );
        // tiles: conv streams b·h·h columns, fc streams b columns, each
        // through P·Q block tiles twice (sign split)
        let (p0, q0) = blocks[0];
        let (p5, q5) = blocks[1];
        let want = 2 * p0 * q0 * (b * h * h) + 2 * p5 * q5 * b;
        prop_assert!(
            sim.tiles_executed == want as u64,
            "tiles {} != expected {want}",
            sim.tiles_executed
        );
        Ok(())
    });
}

#[test]
fn empty_batch_is_empty() {
    let mut g = Gen { rng: cirptc::util::rng::Rng::new(7), seed: 7 };
    let (engine, _, _, _) = random_engine(&mut g);
    let out = engine.forward_batch(&[], &mut Backend::Digital).unwrap();
    assert!(out.is_empty());
}
