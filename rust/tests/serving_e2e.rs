//! End-to-end serving: coordinator + engine backends over the exported
//! test set; checks accuracy ordering (digital >= photonic-with-noise)
//! and metrics plumbing.

use std::path::PathBuf;
use std::sync::Arc;

use cirptc::coordinator::worker::EngineBackend;
use cirptc::coordinator::{BackendFactory, BatcherConfig, Coordinator, InferenceBackend};
use cirptc::data::Bundle;
use cirptc::onn::{Backend, Engine};
use cirptc::simulator::{ChipDescription, ChipSim};
use cirptc::tensor::{argmax, Tensor};

fn artifacts() -> Option<PathBuf> {
    // the crate manifest lives in rust/; artifacts/ sits at the workspace
    // root next to benches/ and examples/
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("artifacts");
    dir.join("models/synth_cxr.json").exists().then_some(dir)
}

fn serve_accuracy(dir: &PathBuf, photonic: bool, n: usize) -> f64 {
    // substrate-specific weights: DPE bundle on the photonic path, the
    // digitally-trained circulant baseline on the digital path (BN
    // calibration follows the execution substrate — compile/recalib.py)
    let variant = if photonic { "dpe" } else { "digital" };
    let bundle = dir.join(format!("models/synth_cxr_{variant}.cpt"));
    let bundle = if bundle.exists() {
        bundle
    } else {
        dir.join("models/synth_cxr_dpe.cpt")
    };
    let engine = Arc::new(
        Engine::load(&dir.join("models/synth_cxr.json"), &bundle).unwrap(),
    );
    let chip = ChipDescription::load(&dir.join("chip.json")).unwrap();
    let test = Bundle::load(&dir.join("models/synth_cxr_testset.cpt")).unwrap();
    let xs = test.get("x").unwrap().as_f32().unwrap();
    let ys = test.get("y").unwrap().as_i32().unwrap();
    let n = n.min(ys.len());
    let images: Vec<Tensor> = (0..n)
        .map(|i| Tensor::new(&[1, 64, 64], xs[i * 64 * 64..(i + 1) * 64 * 64].to_vec()))
        .collect();
    let backends: Vec<BackendFactory> = (0..2)
        .map(|_| {
            let engine = Arc::clone(&engine);
            let chip = chip.clone();
            Box::new(move || {
                let mode = if photonic {
                    Backend::PhotonicSim(ChipSim::new(chip))
                } else {
                    Backend::Digital
                };
                Box::new(EngineBackend { engine, mode })
                    as Box<dyn InferenceBackend>
            }) as BackendFactory
        })
        .collect();
    let coord = Coordinator::start(
        backends,
        BatcherConfig { max_batch: 8, max_wait_us: 1000, queue_cap: 0 },
    );
    let responses = coord.classify_all(&images).unwrap();
    assert_eq!(coord.metrics.completed.get(), n);
    responses
        .iter()
        .zip(ys)
        .filter(|(r, &y)| argmax(&r.logits) == y as usize)
        .count() as f64
        / n as f64
}

#[test]
fn serving_pipeline_digital_and_photonic() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: run `make artifacts` + train");
        return;
    };
    let n = 48; // photonic sim is slow in debug builds; subset suffices
    let acc_digital = serve_accuracy(&dir, false, n);
    let acc_photonic = serve_accuracy(&dir, true, n);
    // the DPE-trained model must classify well above chance (1/3) both
    // digitally and on the noisy simulated chip (paper Fig. 4e ordering)
    assert!(acc_digital > 0.6, "digital acc {acc_digital}");
    assert!(acc_photonic > 0.55, "photonic acc {acc_photonic}");
    assert!(
        acc_digital >= acc_photonic - 0.1,
        "digital {acc_digital} vs photonic {acc_photonic}"
    );
}
