//! End-to-end hardware-aware training (ISSUE 3 acceptance path, the same
//! flow `make train-smoke` drives): pure-rust HAT loop on synthetic data
//! with the **noisy** chip-in-the-loop forward → loss decreases →
//! manifest + CPT1 weights written by rust → reloaded through
//! `onn::Manifest` / `Engine` → a forward batch served.

use cirptc::data::datasets::{self, SHAPES_MANIFEST_JSON as SHAPES};
use cirptc::data::Bundle;
use cirptc::onn::{Backend, Engine, Manifest};
use cirptc::simulator::{ChipDescription, ChipSim};
use cirptc::train::{
    evaluate, fit, gather_batch, Optimizer, TrainBackend, TrainConfig,
    TrainModel,
};

/// A mildly non-ideal chip: 6/4-bit DACs, Γ crosstalk, responsivity tilt,
/// dark current and dynamic noise — the regime hardware-aware training is
/// for.
fn test_chip() -> ChipDescription {
    let mut d = ChipDescription::ideal(4);
    d.gamma = vec![
        0.94, 0.03, 0.02, 0.01, //
        0.02, 0.94, 0.03, 0.01, //
        0.01, 0.03, 0.94, 0.02, //
        0.02, 0.01, 0.03, 0.94,
    ];
    d.resp = vec![1.0, 0.98, 1.02, 0.99];
    d.dark = 0.01;
    d.sigma_rel = 0.01;
    d.sigma_abs = 0.002;
    d.w_bits = 6;
    d.x_bits = 4;
    d.seed = 7;
    d
}

#[test]
fn digital_training_reduces_loss() {
    let manifest = Manifest::parse(SHAPES).unwrap();
    let mut model = TrainModel::init(manifest, 100).unwrap();
    let split = datasets::synth_shapes(96, 101);
    let mut backend = TrainBackend::Digital;
    let mut opt = Optimizer::adam(5e-3);
    let cfg = TrainConfig { epochs: 3, batch: 16, max_steps: 0, seed: 102 };
    let hist = fit(&mut model, &mut backend, &mut opt, &split, &cfg).unwrap();
    assert_eq!(hist.len(), 3);
    assert!(
        hist.last().unwrap() < hist.first().unwrap(),
        "loss must decrease: {hist:?}"
    );
    assert!(*hist.last().unwrap() < 1.0986, "below ln(3): {hist:?}");
}

#[test]
fn sgd_momentum_also_learns() {
    let manifest = Manifest::parse(SHAPES).unwrap();
    let mut model = TrainModel::init(manifest, 110).unwrap();
    let split = datasets::synth_shapes(96, 111);
    let mut backend = TrainBackend::Digital;
    let mut opt = Optimizer::sgd(0.05, 0.9);
    let cfg = TrainConfig { epochs: 3, batch: 16, max_steps: 0, seed: 112 };
    let hist = fit(&mut model, &mut backend, &mut opt, &split, &cfg).unwrap();
    assert!(
        hist.last().unwrap() < hist.first().unwrap(),
        "sgd loss must decrease: {hist:?}"
    );
}

#[test]
fn max_steps_caps_the_run() {
    let manifest = Manifest::parse(SHAPES).unwrap();
    let mut model = TrainModel::init(manifest, 120).unwrap();
    let split = datasets::synth_shapes(64, 121);
    let mut backend = TrainBackend::Digital;
    let mut opt = Optimizer::adam(1e-3);
    let cfg = TrainConfig { epochs: 50, batch: 16, max_steps: 3, seed: 122 };
    let hist = fit(&mut model, &mut backend, &mut opt, &split, &cfg).unwrap();
    // 4 steps/epoch: the cap lands inside epoch 1 → one (partial) entry
    assert_eq!(hist.len(), 1);
}

#[test]
fn chip_in_the_loop_trains_exports_and_serves() {
    let manifest = Manifest::parse(SHAPES).unwrap();
    let mut model = TrainModel::init(manifest.clone(), 200).unwrap();
    let split = datasets::synth_shapes(128, 201);
    let eval_split = datasets::synth_shapes(48, 202);

    // noisy lookup-mode forward (ChipSim::new => noisy = true)
    let mut backend = TrainBackend::Chip(ChipSim::new(test_chip()));
    let mut opt = Optimizer::adam(5e-3);
    let cfg = TrainConfig { epochs: 6, batch: 16, max_steps: 0, seed: 203 };
    let hist = fit(&mut model, &mut backend, &mut opt, &split, &cfg).unwrap();
    assert!(
        hist.last().unwrap() < hist.first().unwrap(),
        "HAT loss must decrease under chip noise: {hist:?}"
    );

    // BN calibration pass (the paper's one-shot chip calibration), then
    // eval through the same chip-in-the-loop path
    let calib: Vec<_> = (0..4)
        .map(|i| {
            let idx: Vec<usize> = (i * 16..(i + 1) * 16).collect();
            gather_batch(&split, &idx).0
        })
        .collect();
    model.recalibrate_bn(&calib, &mut backend).unwrap();
    let acc = evaluate(&model, &mut backend, &eval_split, 16).unwrap();
    assert!(
        acc > 0.40,
        "chip-in-the-loop training should beat chance (got {acc})"
    );

    // rust-written artifacts …
    let dir = std::env::temp_dir().join("cirptc_train_e2e");
    let (mpath, wpath) = model.save_artifacts(&dir, "synth_shapes").unwrap();

    // … reload through the serving stack …
    let engine = Engine::load(&mpath, &wpath).unwrap();
    assert_eq!(engine.manifest.classes, 3);
    assert_eq!(engine.manifest.input_shape(), (1, 16));

    // … and serve a forward batch on both engine backends
    let imgs: Vec<_> = (0..6).map(|i| eval_split.image(i)).collect();
    let logits_dig = engine
        .forward_batch(&imgs, &mut Backend::Digital)
        .unwrap();
    assert_eq!(logits_dig.len(), 6);
    assert!(logits_dig
        .iter()
        .all(|row| row.len() == 3 && row.iter().all(|v| v.is_finite())));
    let sim = ChipSim::deterministic(test_chip());
    let logits_pho = engine
        .forward_batch(&imgs, &mut Backend::PhotonicSim(sim))
        .unwrap();
    assert!(logits_pho
        .iter()
        .all(|row| row.len() == 3 && row.iter().all(|v| v.is_finite())));

    // engine digital forward ≈ trainer eval forward (same math, different
    // accumulation order)
    let (xb, _) = gather_batch(&eval_split, &[0, 1, 2, 3, 4, 5]);
    let trainer_logits = model
        .forward_eval(&xb, &mut TrainBackend::Digital)
        .unwrap();
    for (bi, row) in logits_dig.iter().enumerate() {
        for (c, v) in row.iter().enumerate() {
            let t = trainer_logits.data[bi * 3 + c];
            assert!(
                (t - v).abs() < 1e-2,
                "engine/trainer logit mismatch at ({bi},{c}): {t} vs {v}"
            );
        }
    }
}

#[test]
fn manifest_file_roundtrip() {
    let manifest = Manifest::parse(SHAPES).unwrap();
    let dir = std::env::temp_dir().join("cirptc_manifest_rt");
    let path = dir.join("m.json");
    manifest.save(&path).unwrap();
    let back = Manifest::load(&path).unwrap();
    assert_eq!(manifest, back);
}

#[test]
fn exported_bundle_roundtrips_bytes() {
    let manifest = Manifest::parse(SHAPES).unwrap();
    let model = TrainModel::init(manifest, 300).unwrap();
    let bundle = model.export_bundle();
    let dir = std::env::temp_dir().join("cirptc_bundle_rt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("w.cpt");
    bundle.save(&path).unwrap();
    let back = Bundle::load(&path).unwrap();
    assert_eq!(bundle.tensors.len(), back.tensors.len());
    for (name, entry) in &bundle.tensors {
        assert_eq!(back.get(name).unwrap(), entry, "tensor {name}");
    }
}
