//! End-to-end multi-chip serving farm (DESIGN.md §farm).
//!
//! * **bit-identity** — a partitioned N-chip forward equals the
//!   single-chip engine bit for bit, on the digital backend and on
//!   drift-detached deterministic photonic chips, across random
//!   (P, Q, l, b, N) shapes (the electronic reduce is a row
//!   concatenation in block-row order, so no arithmetic is reordered);
//! * **independent recovery** — K=3 farm members on differently-seeded
//!   drifting chips, each with its own monitor and background
//!   recalibrator, every member recalibrates and returns to `Healthy`
//!   on its own clock while requests keep flowing (zero drops);
//! * **failover** — a member forced to `Failed` mid-stream is routed
//!   around with zero dropped requests, and serves again once restored.
//!
//! Everything is seeded; tests synchronize on shared metrics and
//! per-member drift state, never on sleeps alone.

use std::sync::Arc;
use std::time::{Duration, Instant};

use cirptc::coordinator::{BatcherConfig, Metrics};
use cirptc::data::datasets::{self, Split, SHAPES_MANIFEST_JSON};
use cirptc::data::Bundle;
use cirptc::drift::{
    DriftConfig, DriftModel, DriftMonitor, DriftShared, MonitorConfig,
    RecalConfig, Recalibrator,
};
use cirptc::farm::{
    Farm, FarmConfig, FarmMember, PartitionPlan, PartitionedEngine,
    DEFAULT_DRIFTING_PPM,
};
use cirptc::coordinator::worker;
use cirptc::onn::{Backend, Engine, Manifest};
use cirptc::prop_assert;
use cirptc::simulator::{ChipDescription, ChipSim};
use cirptc::tensor::Tensor;
use cirptc::train::TrainModel;
use cirptc::util::propcheck;
// shared misbehaving/constant backends (promoted from failure_injection)
use cirptc::coordinator::InferenceBackend;
use cirptc::util::testing::{ConstBackend, DeadBackend};

// ---------------------------------------------------------------- shapes

/// Random single-fc model with block grid exactly (P, Q, l): input
/// images are [Q·l, 1, 1], flattened straight into the fc layer.
fn random_fc_engine(g: &mut propcheck::Gen) -> (Arc<Engine>, usize) {
    let l = *g.choose(&[2usize, 4, 8]);
    let p = g.usize_in(1, 4);
    let q = g.usize_in(1, 6);
    let cin = q * l;
    // cout inside ((p-1)·l, p·l] so the padded grid has exactly P rows
    let cout = (p - 1) * l + g.usize_in(1, l);
    let manifest = Manifest::parse(&format!(
        r#"{{
          "dataset": "synth_cxr", "classes": {cout},
          "layers": [
            {{"kind": "flatten", "cin": 0, "cout": 0, "k": 0, "pool": 0,
              "arch": "circ", "l": {l}, "act_scale": 4.0}},
            {{"kind": "fc", "cin": {cin}, "cout": {cout}, "k": 0, "pool": 0,
              "arch": "circ", "l": {l}, "act_scale": 4.0}}
          ]}}"#
    ))
    .unwrap();
    let mut bundle = Bundle::default();
    let w = g.vec_f32(p * q * l, -0.5, 0.5);
    bundle.insert_f32("layer1.w", &[p, q, l], w);
    bundle.insert_f32("layer1.b", &[cout], g.vec_f32(cout, -0.2, 0.2));
    (Arc::new(Engine::from_parts(manifest, &bundle).unwrap()), cin)
}

fn nonideal(l: usize) -> ChipDescription {
    let mut d = ChipDescription::ideal(l);
    d.w_bits = 6;
    d.x_bits = 4;
    d.dark = 0.01;
    d
}

#[test]
fn partitioned_forward_is_bit_identical_across_random_shapes() {
    propcheck::check("farm partition bit-identity", 40, |g| {
        let (engine, cin) = random_fc_engine(g);
        let l = engine.manifest.layers[1].l;
        let b = g.usize_in(1, 4);
        let n = g.usize_in(1, 5);
        let imgs: Vec<Tensor> = (0..b)
            .map(|_| Tensor::new(&[cin, 1, 1], g.vec_f32(cin, 0.0, 1.0)))
            .collect();
        let plan = PartitionPlan::plan(&engine.manifest, n);
        let part = PartitionedEngine::new(Arc::clone(&engine), plan)
            .map_err(|e| format!("plan refused: {e:#}"))?;

        // digital backend
        let want = engine
            .forward_batch(&imgs, &mut Backend::Digital)
            .map_err(|e| format!("single-chip digital: {e:#}"))?;
        let mut chips: Vec<Backend> = (0..n).map(|_| Backend::Digital).collect();
        let got = part
            .forward_batch(&imgs, &mut chips)
            .map_err(|e| format!("partitioned digital: {e:#}"))?;
        prop_assert!(got == want, "digital mismatch at n={n}");

        // drift-detached deterministic photonic chips
        let want = engine
            .forward_batch(
                &imgs,
                &mut Backend::PhotonicSim(ChipSim::deterministic(nonideal(l))),
            )
            .map_err(|e| format!("single-chip photonic: {e:#}"))?;
        let mut chips: Vec<Backend> = (0..n)
            .map(|_| Backend::PhotonicSim(ChipSim::deterministic(nonideal(l))))
            .collect();
        let got = part
            .forward_batch(&imgs, &mut chips)
            .map_err(|e| format!("partitioned photonic: {e:#}"))?;
        prop_assert!(got == want, "photonic mismatch at n={n}");
        Ok(())
    });
}

// ----------------------------------------------------------- drift farm

const K: usize = 3;
const CHUNK: usize = 8;
const PLATEAU_TICKS: u64 = 120;

fn farm_chip(k: usize) -> ChipDescription {
    let mut d = ChipDescription::ideal(4);
    d.w_bits = 6;
    d.x_bits = 4;
    d.dark = 0.01;
    d.seed = 11 ^ k as u64;
    d
}

/// Accelerated per-member drift episode; each member walks on its own
/// seed, so the chips diverge from the calibration point differently.
fn drift_cfg(k: usize) -> DriftConfig {
    DriftConfig {
        seed: 0xFA12 + k as u64,
        passes_per_tick: 1,
        gamma_walk: 1.5e-3,
        resp_tilt: 3e-3,
        dark_creep: 2e-4,
        max_ticks: PLATEAU_TICKS,
    }
}

fn eval_images(split: &Split) -> Vec<Tensor> {
    (0..split.n).map(|i| split.image(i)).collect()
}

/// One pass of `imgs` through the farm in coordinator-sized chunks;
/// panics on any dropped request.
fn serve_round(farm: &Farm, imgs: &[Tensor]) {
    for chunk in imgs.chunks(CHUNK) {
        let responses = farm.coord.classify_all(chunk).unwrap();
        assert_eq!(responses.len(), chunk.len(), "request dropped");
    }
}

#[test]
fn three_drifting_chips_recover_independently_with_zero_drops() {
    let manifest = Manifest::parse(SHAPES_MANIFEST_JSON).unwrap();
    // accuracy is pinned by drift_e2e; here an untrained model keeps the
    // farm variant cheap — what is under test is that every member's own
    // monitor → recalibrator → hot-swap loop closes independently
    let model = TrainModel::init(manifest.clone(), 0xF4).unwrap();
    let bundle = model.export_bundle();
    let eval_split = datasets::synth_shapes(64, 0xF3);
    let imgs = eval_images(&eval_split);
    let metrics = Arc::new(Metrics::default());

    // declared before the farm: recalibrator threads must outlive the
    // member pipelines (their request senders live in the chip hooks)
    let mut recals = Vec::new();
    let mut shared: Vec<Arc<DriftShared>> = Vec::new();
    let mut members = Vec::new();
    for k in 0..K {
        let engine = Engine::from_parts(manifest.clone(), &bundle).unwrap();
        let desc = farm_chip(k);
        let mut sim = ChipSim::deterministic(desc.clone());
        sim.set_drift(DriftModel::new(drift_cfg(k)));
        let monitor = DriftMonitor::new(
            MonitorConfig {
                probe_every: 1,
                residual_trigger: 0.04,
                cooldown_passes: 40,
                ..MonitorConfig::default()
            },
            &desc,
        );
        let (member, recal_rx) = FarmMember::monitored(
            engine,
            sim,
            monitor,
            DEFAULT_DRIFTING_PPM,
            Arc::clone(&metrics),
        );
        let member_shared =
            Arc::clone(member.shared.as_ref().expect("monitored member"));
        recals.push(
            Recalibrator::new(
                model.clone(),
                datasets::synth_shapes(96, 0xF5 + k as u64),
                RecalConfig {
                    fine_tune_steps: 12,
                    lr: 2e-3,
                    batch: 16,
                    bn_batches: 4,
                    seed: 0xF6 + k as u64,
                    noisy: false,
                    snapshot_dir: None,
                },
                Arc::clone(&member_shared),
            )
            .spawn(recal_rx),
        );
        shared.push(member_shared);
        members.push(member);
    }
    let status: Vec<_> = members.iter().map(|m| Arc::clone(&m.status)).collect();
    let farm = Farm::start(
        members,
        FarmConfig {
            batcher: BatcherConfig {
                max_batch: CHUNK,
                max_wait_us: 20_000,
                queue_cap: 1024,
            },
            ..FarmConfig::default()
        },
        Arc::clone(&metrics),
    );

    // serve until every member has recalibrated at least once AND reads
    // Healthy again (its probe residual rebased under the trigger) —
    // each member closes that loop on its own drift clock
    let deadline = Instant::now() + Duration::from_secs(300);
    loop {
        serve_round(&farm, &imgs);
        let recovered = (0..K).all(|k| {
            shared[k].recal_generation.get() >= 1
                && status[k].health() == cirptc::farm::ChipHealth::Healthy
        });
        if recovered {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "farm never recovered: gens {:?}, health {:?}, {}",
            (0..K).map(|k| shared[k].recal_generation.get()).collect::<Vec<_>>(),
            (0..K).map(|k| status[k].health()).collect::<Vec<_>>(),
            metrics.summary()
        );
        std::thread::sleep(Duration::from_millis(20));
    }

    // independence: every member recalibrated on its own stack
    for k in 0..K {
        assert!(
            shared[k].recal_generation.get() >= 1,
            "member {k} never recalibrated"
        );
    }
    assert!(
        metrics.recalibrations.get() >= K,
        "one hot swap per member at minimum: {}",
        metrics.summary()
    );
    // zero drops across the whole episode
    assert_eq!(metrics.errors.get(), 0, "no request may fail");
    assert_eq!(metrics.rejected.get(), 0, "nothing sheds below the cap");
    assert_eq!(
        metrics.completed.get(),
        metrics.submitted.get(),
        "every accepted request must complete"
    );
    // the farm observed its members leaving and re-entering Healthy
    assert!(metrics.farm_transitions.get() >= 1, "{}", metrics.summary());
    drop(farm);
}

#[test]
fn failed_chip_reroutes_with_zero_dropped_requests() {
    let manifest = Manifest::parse(SHAPES_MANIFEST_JSON).unwrap();
    let model = TrainModel::init(manifest.clone(), 0xF7).unwrap();
    let bundle = model.export_bundle();
    let eval_split = datasets::synth_shapes(48, 0xF8);
    let imgs = eval_images(&eval_split);
    let metrics = Arc::new(Metrics::default());

    let engine = Arc::new(Engine::from_parts(manifest, &bundle).unwrap());
    let members: Vec<FarmMember> = (0..K)
        .map(|k| {
            FarmMember::fixed(
                Arc::clone(&engine),
                Backend::PhotonicSim(ChipSim::deterministic(farm_chip(k))),
            )
        })
        .collect();
    let status: Vec<_> = members.iter().map(|m| Arc::clone(&m.status)).collect();
    let farm = Farm::start(
        members,
        FarmConfig {
            batcher: BatcherConfig {
                max_batch: CHUNK,
                max_wait_us: 20_000,
                queue_cap: 0,
            },
            ..FarmConfig::default()
        },
        Arc::clone(&metrics),
    );

    serve_round(&farm, &imgs);
    // kill chip 1 mid-stream: traffic must re-route with zero drops
    status[1].fail();
    serve_round(&farm, &imgs);
    serve_round(&farm, &imgs);
    assert!(
        metrics.farm_rerouted.get() >= 1,
        "traffic must route around the failed member: {}",
        metrics.summary()
    );
    assert!(metrics.farm_transitions.get() >= 1);
    // restore: the member is immediately routable again (no ack protocol)
    status[1].restore();
    serve_round(&farm, &imgs);

    assert_eq!(metrics.errors.get(), 0, "no request may fail");
    assert_eq!(metrics.rejected.get(), 0);
    assert_eq!(
        metrics.completed.get(),
        metrics.submitted.get(),
        "every request must complete"
    );
    assert_eq!(metrics.farm_absorbed.get(), 0, "two chips stayed healthy");
    drop(farm);
}

/// Build a K-member fixed photonic farm over an untrained shapes model,
/// with the given fallback lane attached.
fn fixed_farm_with_fallback(
    fallback: worker::BackendFactory,
    metrics: &Arc<Metrics>,
) -> (Farm, Vec<Arc<cirptc::farm::ChipStatus>>, Vec<Tensor>) {
    let manifest = Manifest::parse(SHAPES_MANIFEST_JSON).unwrap();
    let model = TrainModel::init(manifest.clone(), 0xF9).unwrap();
    let bundle = model.export_bundle();
    let eval_split = datasets::synth_shapes(32, 0xFA);
    let imgs = eval_images(&eval_split);
    let engine = Arc::new(Engine::from_parts(manifest, &bundle).unwrap());
    let members: Vec<FarmMember> = (0..K)
        .map(|k| {
            FarmMember::fixed(
                Arc::clone(&engine),
                Backend::PhotonicSim(ChipSim::deterministic(farm_chip(k))),
            )
        })
        .collect();
    let status: Vec<_> =
        members.iter().map(|m| Arc::clone(&m.status)).collect();
    let farm = Farm::start_with_fallback(
        members,
        Some(fallback),
        FarmConfig {
            batcher: BatcherConfig {
                max_batch: CHUNK,
                max_wait_us: 20_000,
                queue_cap: 0,
            },
            ..FarmConfig::default()
        },
        Arc::clone(metrics),
    );
    (farm, status, imgs)
}

#[test]
fn total_photonic_loss_degrades_to_fallback_with_zero_drops() {
    let metrics = Arc::new(Metrics::default());
    let fallback: worker::BackendFactory =
        Box::new(|| Box::new(ConstBackend) as Box<dyn InferenceBackend>);
    let (farm, status, imgs) = fixed_farm_with_fallback(fallback, &metrics);

    serve_round(&farm, &imgs);
    // every chip member lost: the farm must degrade, not drop
    for st in &status {
        st.quarantine();
    }
    serve_round(&farm, &imgs);
    serve_round(&farm, &imgs);
    assert!(
        metrics.degraded_batches.get() >= 1,
        "total loss must reach the fallback lane: {}",
        metrics.summary()
    );
    assert_eq!(
        metrics.degraded.get(),
        1,
        "the degraded gauge is raised while absorbing on the fallback"
    );
    // recovery: chips restored, traffic returns, the gauge clears
    for st in &status {
        st.restore();
    }
    serve_round(&farm, &imgs);
    assert_eq!(metrics.degraded.get(), 0, "{}", metrics.summary());

    assert_eq!(metrics.errors.get(), 0, "no request may fail");
    assert_eq!(metrics.rejected.get(), 0);
    assert_eq!(
        metrics.completed.get(),
        metrics.submitted.get(),
        "every request must complete, photonic loss or not"
    );
    drop(farm);
}

#[test]
fn healthy_farm_never_touches_a_dead_fallback() {
    // a broken fallback lane must be inert while any chip member serves
    let metrics = Arc::new(Metrics::default());
    let fallback: worker::BackendFactory =
        Box::new(|| Box::new(DeadBackend) as Box<dyn InferenceBackend>);
    let (farm, _status, imgs) = fixed_farm_with_fallback(fallback, &metrics);
    serve_round(&farm, &imgs);
    serve_round(&farm, &imgs);
    assert_eq!(metrics.degraded_batches.get(), 0, "{}", metrics.summary());
    assert_eq!(metrics.errors.get(), 0);
    assert_eq!(metrics.completed.get(), metrics.submitted.get());
    drop(farm);
}
