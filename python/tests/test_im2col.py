"""im2col Pallas kernel vs oracle + the conv-as-BCM-matmul identity (Fig. 1a)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.circulant import bcm_matmul
from compile.kernels.im2col import im2col

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(0, 1, shape).astype(np.float32))


class TestIm2col:
    @pytest.mark.parametrize("c,h,w,k", [
        (1, 5, 5, 3), (3, 8, 8, 3), (3, 32, 32, 3), (2, 7, 9, 5), (4, 6, 6, 1),
    ])
    def test_matches_ref(self, c, h, w, k):
        img = _rand((c, h, w), c + h)
        np.testing.assert_allclose(im2col(img, k), ref.im2col_ref(img, k),
                                   atol=1e-7)

    def test_shape(self):
        img = _rand((3, 10, 12), 1)
        out = im2col(img, 3)
        assert out.shape == (27, 8 * 10)

    @settings(max_examples=15, deadline=None)
    @given(c=st.integers(1, 4), h=st.integers(4, 12), w=st.integers(4, 12),
           k=st.sampled_from([1, 3]), seed=st.integers(0, 999))
    def test_property_matches_ref(self, c, h, w, k, seed):
        img = _rand((c, h, w), seed)
        np.testing.assert_allclose(im2col(img, k), ref.im2col_ref(img, k),
                                   atol=1e-7)

    def test_columns_are_patches(self):
        img = _rand((1, 4, 4), 5)
        out = np.asarray(im2col(img, 3))
        first = np.asarray(img)[0, 0:3, 0:3].reshape(-1)
        np.testing.assert_allclose(out[:, 0], first)


class TestConvViaBcm:
    """The paper's pipeline: im2col -> (padded) BCM matmul == convolution."""

    def test_blur_kernel_paper_fig3(self):
        # 3x3 blur over one channel: 9 inputs padded to 12 -> 12x4 BCM-sized
        # weight exactly as in Fig. 3a ("an addition of 3 rows of padding").
        img = _rand((1, 8, 8), 6)
        blur = jnp.ones((1, 1, 3, 3)) / 9.0
        want = ref.conv2d_ref(img, blur)
        # build a (P=1? no: M rows) — single output map: M=4 (pad to l),
        # N = 9 -> pad to 12 -> Q=3 blocks of l=4
        xmat = ref.im2col_ref(img, 3)                 # (9, 36)
        xpad = jnp.pad(xmat, ((0, 3), (0, 0)))        # (12, 36)
        # one arbitrary kernel occupies one crossbar column after
        # block-circulant extension: here place the flattened kernel in the
        # first dense row by solving for primary vectors directly.
        wdense = jnp.pad(blur.reshape(1, 9), ((0, 0), (0, 3)))  # (1, 12)
        # circulant extension of a single row: w[p=0, q, :] = row segment
        wcomp = wdense.reshape(1, 3, 4)
        y = bcm_matmul(wcomp, xpad)                   # (4, 36); row 0 = conv
        np.testing.assert_allclose(y[0].reshape(6, 6), want[0], atol=1e-5)

    def test_multichannel_conv_identity(self):
        # (Cout, Cin*k*k) dense weight executed as matmul on im2col equals
        # the direct convolution (the transformation in Fig. 1a)
        img = _rand((3, 10, 10), 7)
        kern = _rand((4, 3, 3, 3), 8) - 0.5
        want = ref.conv2d_ref(img, kern)
        xmat = ref.im2col_ref(img, 3)
        wmat = kern.reshape(4, 27)
        got = (wmat @ xmat).reshape(4, 8, 8)
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_padded_rows_are_inert(self):
        # zero-padded input rows never change the result (paper Fig. 3a)
        img = _rand((1, 6, 6), 9)
        xmat = ref.im2col_ref(img, 3)
        xpad = jnp.pad(xmat, ((0, 3), (0, 0)))
        w = _rand((2, 3, 4), 10)
        y_pad = bcm_matmul(w, xpad)
        wdense = ref.expand_bcm(w)[:, :9]
        y_direct = wdense @ xmat
        np.testing.assert_allclose(y_pad, y_direct, atol=1e-5)
