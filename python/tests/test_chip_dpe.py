"""Chip model + DPE: calibration, Γ fitting, STE gradients, sign-splitting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import chip as chip_mod
from compile import dpe as dpe_mod
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _chip(**kw):
    return chip_mod.make_chip(chip_mod.ChipParams(**kw))


def _rand01(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(0, 1, shape).astype(np.float32))


class TestChipModel:
    def test_ideal_chip_is_exact_bcm(self):
        chp = _chip(eps=0.0, dark=0.0, resp_tilt=0.0, fab_sigma=0.0,
                    w_bits=0, x_bits=0)
        w, x = _rand01((2, 3, 4), 1), _rand01((12, 5), 2)
        np.testing.assert_allclose(chp.forward(w, x),
                                   ref.bcm_matmul_ref(w, x), atol=1e-5)

    def test_deterministic_without_key(self):
        chp = _chip()
        w, x = _rand01((2, 2, 4), 3), _rand01((8, 4), 4)
        np.testing.assert_allclose(chp.forward(w, x), chp.forward(w, x))

    def test_noise_with_key(self):
        chp = _chip()
        w, x = _rand01((2, 2, 4), 5), _rand01((8, 4), 6)
        y1 = chp.forward(w, x, jax.random.PRNGKey(0))
        y2 = chp.forward(w, x, jax.random.PRNGKey(1))
        assert not np.allclose(y1, y2)

    def test_seed_reproducible_instance(self):
        a, b = _chip(seed=5), _chip(seed=5)
        np.testing.assert_allclose(a.gamma_true, b.gamma_true)
        np.testing.assert_allclose(a.resp, b.resp)

    def test_different_seed_different_fab(self):
        a, b = _chip(seed=5), _chip(seed=6)
        assert not np.allclose(a.gamma_true, b.gamma_true)

    def test_export_dict_roundtrip_fields(self):
        d = _chip().export_dict()
        for k in ("l", "eps", "dark", "gamma_true", "resp", "w_bits",
                  "x_bits", "sigma_rel", "sigma_abs", "seed"):
            assert k in d
        assert np.asarray(d["gamma_true"]).shape == (4, 4)


class TestCalibration:
    def test_gamma_fit_recovers_truth(self):
        chp = _chip(sigma_rel=0.0, sigma_abs=0.0)   # noiseless sweep
        lut = chp.sweep_lut(jax.random.PRNGKey(0), n_sweep=160)
        gamma_hat, dark_hat, _ = chp.fit_gamma(lut)
        # The tilt acts on the weight's wavelength index (c-r) mod l, which
        # no single Γ can represent exactly — the DPE is an approximation by
        # construction (paper: "we approximate its behavior").  diag(resp)@Γ
        # is the nearest interpretable target; the residual is the tilt's
        # off-row component, bounded by ~resp_tilt.
        target = np.diag(np.asarray(chp.resp)) @ np.asarray(chp.gamma_true)
        assert np.abs(np.asarray(gamma_hat) - target).max() < 2.5e-2
        np.testing.assert_allclose(dark_hat, chp.p.dark * np.ones(4),
                                   atol=1e-2)

    def test_gamma_fit_robust_to_noise(self):
        chp = _chip()
        lut = chp.sweep_lut(jax.random.PRNGKey(1), n_sweep=256)
        gamma_hat, _, _ = chp.fit_gamma(lut)
        target = np.diag(np.asarray(chp.resp)) @ np.asarray(chp.gamma_true)
        assert np.abs(np.asarray(gamma_hat) - target).max() < 5e-2


class TestSTE:
    def test_forward_quantizes(self):
        x = _rand01((64,), 7)
        np.testing.assert_allclose(dpe_mod.ste_quantize(x, 4),
                                   ref.quantize_ref(x, 4), atol=1e-7)

    def test_gradient_is_identity_inside_range(self):
        g = jax.grad(lambda x: jnp.sum(dpe_mod.ste_quantize(x, 4)))(
            jnp.asarray([0.3, 0.7]))
        np.testing.assert_allclose(g, [1.0, 1.0])

    def test_gradient_zero_outside_range(self):
        g = jax.grad(lambda x: jnp.sum(dpe_mod.ste_quantize(x, 4)))(
            jnp.asarray([-0.5, 1.5]))
        np.testing.assert_allclose(g, [0.0, 0.0])


class TestSignSplit:
    def test_reconstruction(self):
        w = _rand01((3, 3, 4), 8) - 0.5
        wp, wn, s = dpe_mod.split_signed(w)
        np.testing.assert_allclose((wp - wn) * s, w, atol=1e-6)

    def test_halves_nonnegative_unit_range(self):
        w = 10.0 * (_rand01((2, 2, 4), 9) - 0.5)
        wp, wn, _ = dpe_mod.split_signed(w)
        for h in (wp, wn):
            assert float(jnp.min(h)) >= 0.0 and float(jnp.max(h)) <= 1.0

    def test_signed_forward_cancels_dark(self):
        # dark offset identical in both passes -> exact cancellation
        d = dpe_mod.DpeParams(l=4, gamma_hat=jnp.eye(4),
                              dark_hat=jnp.full((4,), 0.3),
                              resp_hat=jnp.ones(4), w_bits=0, x_bits=0,
                              noise_rel=0.0, noise_abs=0.0)
        w = _rand01((2, 2, 4), 10) - 0.5
        x = _rand01((8, 4), 11)
        y = dpe_mod.signed_dpe_forward(w, x, d)
        np.testing.assert_allclose(y, ref.bcm_matmul_ref(w, x), atol=1e-5)


class TestDpeSurrogate:
    def test_ideal_dpe_equals_bcm(self):
        d = dpe_mod.ideal_dpe(4)
        w, x = _rand01((2, 3, 4), 12), _rand01((12, 6), 13)
        np.testing.assert_allclose(dpe_mod.dpe_forward(w, x, d),
                                   ref.bcm_matmul_ref(w, x), atol=1e-5)

    def test_gamma_big_blockdiag(self):
        g = jnp.asarray(np.random.default_rng(0)
                        .uniform(size=(4, 4)).astype(np.float32))
        dd = dpe_mod.DpeParams(l=4, gamma_hat=g, dark_hat=jnp.zeros(4),
                               resp_hat=jnp.ones(4))
        big = np.asarray(dd.gamma_big(3))
        assert big.shape == (12, 12)
        for i in range(3):
            np.testing.assert_allclose(big[i * 4:(i + 1) * 4,
                                           i * 4:(i + 1) * 4], g)
        assert np.abs(big[0:4, 4:8]).max() == 0.0

    def test_surrogate_tracks_chip(self):
        """DPE built from the chip's true params == deterministic chip."""
        chp = _chip(sigma_rel=0.0, sigma_abs=0.0)
        d = dpe_mod.DpeParams(
            l=4, gamma_hat=chp.gamma_true,
            dark_hat=jnp.full((4,), chp.p.dark), resp_hat=chp.resp,
            w_bits=6, x_bits=4, noise_rel=0.0, noise_abs=0.0)
        w, x = _rand01((3, 2, 4), 14), _rand01((8, 5), 15)
        np.testing.assert_allclose(dpe_mod.dpe_forward(w, x, d),
                                   chp.forward(w, x), atol=1e-5)

    def test_gradients_flow_to_w_and_x(self):
        chp = _chip()
        d = dpe_mod.DpeParams(l=4, gamma_hat=chp.gamma_true,
                              dark_hat=jnp.zeros(4), resp_hat=chp.resp)
        w, x = _rand01((2, 2, 4), 16), _rand01((8, 3), 17)
        gw = jax.grad(lambda w: jnp.sum(dpe_mod.dpe_forward(w, x, d)))(w)
        gx = jax.grad(lambda x: jnp.sum(dpe_mod.dpe_forward(w, x, d)))(x)
        assert float(jnp.abs(gw).max()) > 0.0
        assert float(jnp.abs(gx).max()) > 0.0
