"""CPT1 bundle round-trip + AOT HLO-text artifact properties."""

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import export, model
from compile.aot import to_hlo_text
from compile.kernels.circulant import bcm_matmul

jax.config.update("jax_platform_name", "cpu")

ARTIFACTS = Path(__file__).resolve().parents[2] / "artifacts"


class TestBundle:
    def test_roundtrip(self, tmp_path):
        rng = np.random.default_rng(0)
        tensors = {
            "a.w": rng.normal(size=(3, 4, 5)).astype(np.float32),
            "b": rng.integers(0, 10, (7,)).astype(np.int32),
            "scalar": np.float32(3.5).reshape(()),
        }
        p = tmp_path / "t.cpt"
        export.write_bundle(p, tensors)
        back = export.read_bundle(p)
        assert set(back) == set(tensors)
        for k in tensors:
            np.testing.assert_allclose(back[k], tensors[k])
            assert back[k].dtype == tensors[k].dtype

    def test_model_tensors_flatten(self):
        cfgs = model.net_config("synth_cxr", "circ")
        params, state = model.init_params(jax.random.PRNGKey(0), cfgs)
        t = export.model_tensors(params, state)
        assert any(k.endswith(".w") for k in t)
        assert any(".state.mean" in k for k in t)

    def test_manifest(self, tmp_path):
        cfgs = model.net_config("synth_cxr", "circ")
        export.write_manifest(tmp_path / "m.json", cfgs, {"dataset": "x"})
        m = json.loads((tmp_path / "m.json").read_text())
        assert m["dataset"] == "x"
        assert m["layers"][0]["kind"] == "conv"
        assert m["layers"][0]["l"] == 4


class TestHloText:
    def test_lowering_has_entry_and_no_elision(self):
        w = jax.ShapeDtypeStruct((2, 3, 4), jnp.float32)
        x = jax.ShapeDtypeStruct((12, 4), jnp.float32)
        fn = lambda w, x: (bcm_matmul(w, x),)
        text = to_hlo_text(jax.jit(fn).lower(w, x))
        assert "ENTRY" in text
        assert "{...}" not in text          # constants must not be elided

    def test_baked_constants_survive(self):
        big = jnp.asarray(np.random.default_rng(1)
                          .normal(size=(32, 32)).astype(np.float32))
        fn = lambda x: (big @ x,)
        text = to_hlo_text(jax.jit(fn).lower(
            jax.ShapeDtypeStruct((32, 4), jnp.float32)))
        assert "{...}" not in text
        assert "f32[32,32]" in text


@pytest.mark.skipif(not (ARTIFACTS / "manifest.json").exists(),
                    reason="run `make artifacts` first")
class TestArtifactsOnDisk:
    def test_manifest_lists_all_hlo(self):
        listed = set(json.loads((ARTIFACTS / "manifest.json").read_text()))
        on_disk = {p.name for p in ARTIFACTS.glob("*.hlo.txt")}
        assert listed == on_disk
        assert len(listed) >= 12

    def test_artifacts_not_elided(self):
        for p in ARTIFACTS.glob("*.hlo.txt"):
            assert "{...}" not in p.read_text(), p.name

    def test_chip_json_consistent(self):
        d = json.loads((ARTIFACTS / "chip.json").read_text())
        g = np.asarray(d["gamma_true"])
        assert g.shape == (d["l"], d["l"])
        np.testing.assert_allclose(g.sum(axis=1), 1.0, atol=0.05)

    def test_goldens_cover_cases(self):
        g = export.read_bundle(ARTIFACTS / "goldens.cpt")
        cases = {k.split(".")[0] for k in g}
        assert len(cases) >= 4
        for c in cases:
            assert {f"{c}.w", f"{c}.x", f"{c}.y"} <= set(g)
