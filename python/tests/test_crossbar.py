"""Crossbar (photonic-forward) kernel and transfer-chain oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.crossbar import crossbar_forward

jax.config.update("jax_platform_name", "cpu")


def _rand01(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(0, 1, shape).astype(np.float32))


class TestQuantizer:
    @pytest.mark.parametrize("bits", [1, 2, 4, 6, 8])
    def test_levels(self, bits):
        x = jnp.linspace(0, 1, 1000)
        q = np.unique(np.asarray(ref.quantize_ref(x, bits)))
        assert len(q) == (1 << bits)

    def test_endpoints_exact(self):
        for bits in (4, 6):
            q = ref.quantize_ref(jnp.asarray([0.0, 1.0]), bits)
            np.testing.assert_allclose(q, [0.0, 1.0])

    def test_error_bound(self):
        x = _rand01((1000,), 1)
        for bits in (4, 6):
            err = np.abs(np.asarray(ref.quantize_ref(x, bits) - x))
            assert err.max() <= 0.5 / ((1 << bits) - 1) + 1e-7

    def test_idempotent(self):
        x = _rand01((100,), 2)
        q1 = ref.quantize_ref(x, 4)
        np.testing.assert_allclose(ref.quantize_ref(q1, 4), q1, atol=1e-7)

    def test_clips_out_of_range(self):
        q = ref.quantize_ref(jnp.asarray([-0.5, 1.5]), 4)
        np.testing.assert_allclose(q, [0.0, 1.0])


class TestCrosstalkMatrix:
    def test_rows_sum_to_one(self):
        for n in (2, 4, 8, 48):
            g = np.asarray(ref.crosstalk_matrix(n, 0.03))
            np.testing.assert_allclose(g.sum(axis=1), np.ones(n), atol=1e-6)

    def test_zero_eps_is_identity(self):
        g = np.asarray(ref.crosstalk_matrix(4, 0.0))
        np.testing.assert_allclose(g, np.eye(4), atol=1e-7)

    def test_decaying_leakage(self):
        # row normalisation breaks exact symmetry at the band edges (edge
        # channels have fewer neighbours) — only the decay is invariant
        g = np.asarray(ref.crosstalk_matrix(6, 0.05))
        assert np.abs(g - g.T).max() < 0.01
        assert g[0, 0] > g[0, 1] > g[0, 2] > g[0, 3]


class TestDeviceModels:
    def test_mzm_roundtrip(self):
        x = _rand01((256,), 3)
        v = ref.mzm_drive(x)
        np.testing.assert_allclose(ref.mzm_transmission(v), x, atol=1e-6)

    def test_mzm_monotone(self):
        v = jnp.linspace(0, 1, 100)
        t = np.asarray(ref.mzm_transmission(v))
        assert np.all(np.diff(t) >= -1e-7)

    def test_mrr_roundtrip(self):
        w = jnp.asarray(np.linspace(0.01, 1.0, 100, dtype=np.float32))
        d = ref.mrr_weight_detuning(w)
        np.testing.assert_allclose(ref.mrr_drop_transmission(d), w, atol=1e-5)

    def test_mrr_peak_at_resonance(self):
        t = np.asarray(ref.mrr_drop_transmission(jnp.asarray([0.0]), peak=0.9))
        np.testing.assert_allclose(t, [0.9])

    def test_mrr_fwhm_definition(self):
        # at delta = fwhm/2 the transmission is half the peak
        t = ref.mrr_drop_transmission(jnp.asarray([0.5]), fwhm=1.0, peak=1.0)
        np.testing.assert_allclose(t, [0.5], atol=1e-6)


class TestCrossbarKernel:
    @pytest.mark.parametrize("p,q,l,b", [(1, 1, 4, 1), (3, 5, 4, 8),
                                         (12, 12, 4, 16), (2, 2, 8, 4)])
    def test_matches_ref(self, p, q, l, b):
        w, x = _rand01((p, q, l), p), _rand01((q * l, b), q)
        g = ref.crosstalk_matrix(l, 0.02)
        got = crossbar_forward(w, x, g, dark=0.015)
        want = ref.crossbar_forward_ref(w, x, eps=0.02, w_bits=6,
                                        x_bits=4, dark=0.015)
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_no_quant_no_talk_equals_bcm(self):
        w, x = _rand01((2, 3, 4), 7), _rand01((12, 4), 8)
        g = jnp.eye(4)
        got = crossbar_forward(w, x, g, w_bits=0, x_bits=0, dark=0.0)
        np.testing.assert_allclose(got, ref.bcm_matmul_ref(w, x), atol=1e-5)

    def test_dark_offset_additive(self):
        w, x = _rand01((2, 2, 4), 9), _rand01((8, 2), 10)
        g = ref.crosstalk_matrix(4, 0.01)
        y0 = crossbar_forward(w, x, g, dark=0.0)
        y1 = crossbar_forward(w, x, g, dark=0.25)
        np.testing.assert_allclose(y1 - y0, 0.25 * np.ones_like(y0), atol=1e-6)

    def test_outputs_nonnegative(self):
        # positive weights, positive inputs => nonnegative photocurrent
        w, x = _rand01((3, 3, 4), 11), _rand01((12, 6), 12)
        g = ref.crosstalk_matrix(4, 0.05)
        assert np.all(np.asarray(crossbar_forward(w, x, g)) >= 0.0)

    @settings(max_examples=15, deadline=None)
    @given(p=st.integers(1, 4), q=st.integers(1, 4), b=st.integers(1, 6),
           eps=st.floats(0.0, 0.1), seed=st.integers(0, 2 ** 16))
    def test_property_matches_ref(self, p, q, b, eps, seed):
        l = 4
        w, x = _rand01((p, q, l), seed), _rand01((q * l, b), seed + 1)
        g = ref.crosstalk_matrix(l, eps)
        got = crossbar_forward(w, x, g, dark=0.01)
        want = ref.crossbar_forward_ref(w, x, eps=eps, w_bits=6, x_bits=4,
                                        dark=0.01)
        np.testing.assert_allclose(got, want, atol=1e-4)
