"""Pallas block-circulant matmul kernel vs pure-jnp oracle (the CORE signal)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.circulant import bcm_matmul, bcm_matmul_fft

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed=0, lo=-1.0, hi=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(lo, hi, shape).astype(np.float32))


# ---------------------------------------------------------------------------
# spec sanity: the oracle itself
# ---------------------------------------------------------------------------

class TestOracle:
    def test_expand_circulant_rows_are_rotations(self):
        w = _rand((4,), seed=1)
        c = np.asarray(ref.expand_circulant(w))
        for r in range(4):
            # row r of a circulant with primary row w is w rotated right by r
            assert np.allclose(c[r], np.roll(np.asarray(w), r))

    def test_expand_matches_paper_eq1_order2(self):
        # explicit 2x2 check of Eq. (1): [[w1, w2], [w2, w1]]
        w = jnp.asarray([1.0, 2.0])
        c = np.asarray(ref.expand_circulant(w))
        assert np.allclose(c, [[1.0, 2.0], [2.0, 1.0]])

    def test_expand_bcm_block_structure(self):
        w = _rand((2, 3, 4), seed=2)
        dense = np.asarray(ref.expand_bcm(w))
        assert dense.shape == (8, 12)
        for p in range(2):
            for q in range(3):
                blk = dense[p * 4:(p + 1) * 4, q * 4:(q + 1) * 4]
                assert np.allclose(blk, ref.expand_circulant(w[p, q]))

    def test_fft_path_equals_dense_expansion(self):
        w, x = _rand((3, 4, 4), 3), _rand((16, 8), 4)
        y0 = ref.bcm_matmul_ref(w, x)
        y1 = ref.bcm_matmul_fft_ref(w, x)
        np.testing.assert_allclose(y0, y1, atol=1e-4)

    def test_parameter_reduction_factor(self):
        # paper: independent parameters reduce to MN/l
        p, q, l = 5, 7, 4
        assert p * q * l == (p * l) * (q * l) // l


# ---------------------------------------------------------------------------
# Pallas kernel vs oracle
# ---------------------------------------------------------------------------

class TestPallasKernel:
    @pytest.mark.parametrize("p,q,l,b", [
        (1, 1, 2, 1), (2, 3, 4, 8), (4, 4, 4, 16), (3, 5, 8, 4),
        (12, 12, 4, 16), (1, 8, 16, 2),
    ])
    def test_matches_ref(self, p, q, l, b):
        w, x = _rand((p, q, l), p + q), _rand((q * l, b), l + b)
        np.testing.assert_allclose(
            bcm_matmul(w, x), ref.bcm_matmul_ref(w, x), atol=1e-5)

    @pytest.mark.parametrize("bt", [1, 2, 4, 8])
    def test_batch_tiling_invariant(self, bt):
        w, x = _rand((3, 4, 4), 5), _rand((16, 8), 6)
        full = bcm_matmul(w, x)
        tiled = bcm_matmul(w, x, batch_tile=bt)
        np.testing.assert_allclose(full, tiled, atol=1e-6)

    def test_non_divisible_batch_tile_falls_back(self):
        w, x = _rand((2, 2, 4), 7), _rand((8, 7), 8)
        np.testing.assert_allclose(
            bcm_matmul(w, x, batch_tile=3), ref.bcm_matmul_ref(w, x),
            atol=1e-5)

    @pytest.mark.parametrize("p,q,l,b", [(2, 3, 4, 8), (4, 2, 8, 4)])
    def test_fft_kernel_matches_ref(self, p, q, l, b):
        w, x = _rand((p, q, l), 9), _rand((q * l, b), 10)
        np.testing.assert_allclose(
            bcm_matmul_fft(w, x), ref.bcm_matmul_ref(w, x), atol=1e-3,
            rtol=1e-3)

    @settings(max_examples=25, deadline=None)
    @given(p=st.integers(1, 6), q=st.integers(1, 6),
           le=st.sampled_from([2, 4, 8]), b=st.integers(1, 9),
           seed=st.integers(0, 2 ** 16))
    def test_property_matches_ref(self, p, q, le, b, seed):
        w = _rand((p, q, le), seed)
        x = _rand((q * le, b), seed + 1)
        np.testing.assert_allclose(
            bcm_matmul(w, x), ref.bcm_matmul_ref(w, x), atol=1e-4)

    def test_linearity(self):
        w, x1, x2 = _rand((2, 2, 4), 11), _rand((8, 4), 12), _rand((8, 4), 13)
        y = bcm_matmul(w, x1 + 2.0 * x2)
        np.testing.assert_allclose(
            y, bcm_matmul(w, x1) + 2.0 * bcm_matmul(w, x2), atol=1e-5)

    def test_identity_weight(self):
        # primary vector e0 per diagonal block => identity BCM
        l, q = 4, 3
        w = np.zeros((q, q, l), np.float32)
        for i in range(q):
            w[i, i, 0] = 1.0
        x = _rand((q * l, 5), 14)
        np.testing.assert_allclose(bcm_matmul(jnp.asarray(w), x), x, atol=1e-6)

    def test_dtype_f32_output(self):
        w, x = _rand((2, 2, 4), 15), _rand((8, 4), 16)
        assert bcm_matmul(w, x).dtype == jnp.float32
