"""StrC-ONN model: shapes, parameter accounting, digital/device consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import chip as chip_mod
from compile import data as data_mod
from compile import dpe as dpe_mod
from compile import model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def small_batch():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.uniform(0, 1, (4, 3, 32, 32)).astype(np.float32))


class TestShapes:
    @pytest.mark.parametrize("name,cin,h,nc", [
        ("synth_digits", 3, 32, 10), ("synth_textures", 3, 32, 10),
        ("synth_cxr", 1, 64, 3),
    ])
    @pytest.mark.parametrize("arch", ["gemm", "circ"])
    def test_forward_shapes(self, name, cin, h, nc, arch):
        cfgs = model.net_config(name, arch)
        params, state = model.init_params(jax.random.PRNGKey(0), cfgs)
        x = jnp.zeros((2, cin, h, h))
        logits, _ = model.apply(params, state, cfgs, x)
        assert logits.shape == (2, nc)

    def test_unknown_dataset_raises(self):
        with pytest.raises(ValueError):
            model.net_config("nope", "circ")


class TestParamAccounting:
    def test_reduction_near_paper_value(self):
        # paper: "up to a 74.91% reduction in trainable parameters";
        # order-4 compression is bounded by 75%, approached as padding
        # overhead vanishes.
        for name in data_mod.DATASETS:
            c = model.count_params(model.net_config(name, "circ"))
            assert 74.0 < c["reduction_pct"] <= 75.0

    def test_circ_params_equal_stored_size(self):
        cfgs = model.net_config("synth_cxr", "circ")
        params, _ = model.init_params(jax.random.PRNGKey(0), cfgs)
        stored = sum(int(np.prod(p["w"].shape))
                     for p in params.values() if "w" in p)
        assert stored == model.count_params(cfgs)["circ"]

    def test_gemm_params_equal_stored_size(self):
        cfgs = model.net_config("synth_digits", "gemm")
        params, _ = model.init_params(jax.random.PRNGKey(0), cfgs)
        stored = sum(int(np.prod(p["w"].shape))
                     for p in params.values() if "w" in p)
        assert stored == model.count_params(cfgs)["gemm"]


class TestDeviceDigitalConsistency:
    def test_ideal_device_matches_digital(self, small_batch):
        """With an ideal chip (identity Γ, no quant/noise/tilt) and no
        dynamic-range clipping, the device path must reproduce the digital
        path — the key consistency invariant between training and
        deployment.  act_scale is raised so no activation clips (with 0-bit
        quantization the scale costs no precision)."""
        import dataclasses
        cfgs = [dataclasses.replace(c, act_scale=1e4)
                for c in model.net_config("synth_textures", "circ")]
        params, state = model.init_params(jax.random.PRNGKey(1), cfgs)
        d = dpe_mod.ideal_dpe(4)
        y_dig, _ = model.apply(params, state, cfgs, small_batch,
                               mode="digital")
        y_dev, _ = model.apply(params, state, cfgs, small_batch,
                               mode="device", dpe=d)
        np.testing.assert_allclose(y_dig, y_dev, atol=5e-3, rtol=1e-3)

    def test_device_clipping_bounds_range(self, small_batch):
        """The device path's finite dynamic range (act_scale) clips large
        activations — deliberate CirPTC behaviour the DPE trains through."""
        cfgs = model.net_config("synth_textures", "circ")
        params, state = model.init_params(jax.random.PRNGKey(1), cfgs)
        d = dpe_mod.ideal_dpe(4)
        y_dig, _ = model.apply(params, state, cfgs, small_batch,
                               mode="digital")
        y_dev, _ = model.apply(params, state, cfgs, small_batch,
                               mode="device", dpe=d)
        # clipping only shrinks activations, never grows them unboundedly
        assert float(jnp.abs(y_dev).max()) <= float(jnp.abs(y_dig).max()) * 3

    def test_device_quantization_changes_output(self, small_batch):
        cfgs = model.net_config("synth_textures", "circ")
        params, state = model.init_params(jax.random.PRNGKey(2), cfgs)
        chp = chip_mod.make_chip(chip_mod.ChipParams())
        d = dpe_mod.DpeParams(l=4, gamma_hat=chp.gamma_true,
                              dark_hat=jnp.zeros(4), resp_hat=chp.resp,
                              w_bits=6, x_bits=4)
        y_dig, _ = model.apply(params, state, cfgs, small_batch,
                               mode="digital")
        y_dev, _ = model.apply(params, state, cfgs, small_batch,
                               mode="device", dpe=d)
        assert not np.allclose(np.asarray(y_dig), np.asarray(y_dev),
                               atol=1e-4)

    def test_device_noise_stochastic(self, small_batch):
        cfgs = model.net_config("synth_textures", "circ")
        params, state = model.init_params(jax.random.PRNGKey(3), cfgs)
        d = dpe_mod.ideal_dpe(4)
        d = dpe_mod.DpeParams(**{**d.__dict__, "noise_rel": 0.05})
        y1, _ = model.apply(params, state, cfgs, small_batch, mode="device",
                            dpe=d, key=jax.random.PRNGKey(1))
        y2, _ = model.apply(params, state, cfgs, small_batch, mode="device",
                            dpe=d, key=jax.random.PRNGKey(2))
        assert not np.allclose(np.asarray(y1), np.asarray(y2))


class TestBatchNorm:
    def test_train_updates_state(self):
        cfgs = model.net_config("synth_textures", "circ")
        params, state = model.init_params(jax.random.PRNGKey(4), cfgs)
        x = jnp.ones((4, 3, 32, 32)) * 0.5
        _, st2 = model.apply(params, state, cfgs, x, train=True)
        changed = any(
            not np.allclose(st2[k]["mean"], state[k]["mean"])
            for k in state)
        assert changed

    def test_eval_does_not_update_state(self):
        cfgs = model.net_config("synth_textures", "circ")
        params, state = model.init_params(jax.random.PRNGKey(5), cfgs)
        x = jnp.ones((4, 3, 32, 32)) * 0.5
        _, st2 = model.apply(params, state, cfgs, x, train=False)
        for k in state:
            np.testing.assert_allclose(st2[k]["mean"], state[k]["mean"])

    def test_momentum_zero_gives_batch_stats(self):
        cfgs = [model.LayerCfg("bn", cin=3)]
        params, state = model.init_params(jax.random.PRNGKey(6), cfgs)
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(2.0, 3.0, (16, 3, 8, 8)).astype(np.float32))
        _, st2 = model.apply(params, state, cfgs, x, train=True,
                             bn_momentum=0.0)
        np.testing.assert_allclose(st2["layer0"]["mean"],
                                   x.mean(axis=(0, 2, 3)), atol=1e-5)


class TestDatasets:
    @pytest.mark.parametrize("name", list(data_mod.DATASETS))
    def test_shapes_ranges_determinism(self, name):
        ds1 = data_mod.DATASETS[name](n_train=32, n_test=16)
        ds2 = data_mod.DATASETS[name](n_train=32, n_test=16)
        assert ds1["train_x"].shape[0] == 32
        assert ds1["train_x"].min() >= 0.0 and ds1["train_x"].max() <= 1.0
        assert ds1["train_y"].min() >= 0
        assert ds1["train_y"].max() < ds1["classes"]
        np.testing.assert_allclose(ds1["train_x"], ds2["train_x"])

    @pytest.mark.parametrize("name", list(data_mod.DATASETS))
    def test_all_classes_present(self, name):
        ds = data_mod.DATASETS[name](n_train=256, n_test=64)
        assert len(np.unique(ds["train_y"])) == ds["classes"]
