"""Differentiable PIC Estimator (DPE) — paper Methods, "Hardware-Aware Training".

Two modes, exactly as the paper defines them:

* **lookup mode** — inference against the (non-differentiable) chip
  response.  Here that is :meth:`chip.PhotonicChip.forward`; on the rust
  side it is the simulator.
* **differentiable mode** — a surrogate ``Y'(w, x) = W · Γ̂ x`` (paper's
  ``W · Γx``) with Γ̂, dark offset and responsivity fitted from the
  calibration LUT, plus straight-through-estimator quantization and dynamic
  Gaussian noise injection, so gradients flow to both ``w`` and ``x`` while
  the forward pass statistically matches the chip.

The key identity used to keep training fast: the per-block mixing Γ acting
on length-``l`` input subgroups equals a right-multiplication of the dense
weight by ``Γ_big = blockdiag(Γ, ..., Γ)``; and the responsivity tilt is a
row-space modulation of the compressed weights.  Both therefore fold into
an *effective dense weight*, so hardware-aware training runs at the speed
of ordinary dense training.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .kernels import ref


def ste_quantize(x: jnp.ndarray, bits: int, lo: float = 0.0,
                 hi: float = 1.0) -> jnp.ndarray:
    """Straight-through-estimator quantization: forward quantizes,
    backward is identity (gradient of clip outside [lo, hi] is zero)."""
    xc = jnp.clip(x, lo, hi)
    q = ref.quantize_ref(xc, bits, lo, hi)
    return xc + jax.lax.stop_gradient(q - xc)


@dataclasses.dataclass(frozen=True)
class DpeParams:
    """Fitted chip estimate + training-noise configuration."""
    l: int
    gamma_hat: jnp.ndarray       # (l, l) fitted mixing operator
    dark_hat: jnp.ndarray        # (l,) fitted dark offsets (per block-row λ)
    resp_hat: jnp.ndarray        # (l,) fitted responsivity tilt
    w_bits: int = 6
    x_bits: int = 4
    noise_rel: float = 0.01      # dynamic noise injection magnitudes
    noise_abs: float = 0.003

    def gamma_big(self, q: int) -> jnp.ndarray:
        """blockdiag(Γ̂, ..., Γ̂) of size (q*l, q*l)."""
        eye = jnp.eye(q, dtype=self.gamma_hat.dtype)
        return jnp.kron(eye, self.gamma_hat)


def ideal_dpe(l: int, w_bits: int = 0, x_bits: int = 0) -> DpeParams:
    """A DPE describing a perfect chip (identity Γ, no dark/tilt/noise).
    With ``w_bits = x_bits = 0`` this reduces circulant training to plain
    digital circulant training — used for the Fig. 4e digital baselines."""
    return DpeParams(l=l, gamma_hat=jnp.eye(l), dark_hat=jnp.zeros(l),
                     resp_hat=jnp.ones(l), w_bits=w_bits, x_bits=x_bits,
                     noise_rel=0.0, noise_abs=0.0)


def effective_dense_weight(w: jnp.ndarray, dpe: DpeParams,
                           quantize: bool = True) -> jnp.ndarray:
    """Fold quantization (STE), responsivity and Γ̂ into a dense (M, N) weight.

    ``w`` is the compressed (P, Q, l) *device-domain* weight in [0, 1].
    Returns ``diag-resp(expand(q(w))) @ Γ_big`` so that ``W_eff @ x``
    reproduces the DPE surrogate ``resp ∘ (W Γ̂ x)``.
    """
    p, q, l = w.shape
    wq = ste_quantize(w, dpe.w_bits) if (quantize and dpe.w_bits) else w
    wr = wq * dpe.resp_hat[None, None, :]
    dense = ref.expand_bcm(wr)                        # (P*l, Q*l)
    return dense @ dpe.gamma_big(q)


def dpe_forward(w: jnp.ndarray, x: jnp.ndarray, dpe: DpeParams,
                key: jax.Array | None = None) -> jnp.ndarray:
    """Differentiable-mode surrogate of one on-chip BCM matmul.

    w: (P, Q, l) in [0, 1];  x: (N, B) in [0, 1];  returns (M, B) with the
    dark offset *included* (sign-split post-processing subtracts it; see
    :func:`signed_dpe_forward`).
    """
    p, q, l = w.shape
    xq = ste_quantize(x, dpe.x_bits) if dpe.x_bits else x
    w_eff = effective_dense_weight(w, dpe)
    y = w_eff @ xq + jnp.tile(dpe.dark_hat, p)[:, None]
    if key is not None and (dpe.noise_rel > 0 or dpe.noise_abs > 0):
        k1, k2 = jax.random.split(key)
        y = y + (jnp.abs(jax.lax.stop_gradient(y)) * dpe.noise_rel
                 * jax.random.normal(k1, y.shape)
                 + dpe.noise_abs * jax.random.normal(k2, y.shape))
    return y


def split_signed(w: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full-range weights -> (w_pos, w_neg, scale) in the device domain.

    Paper "On-chip image processing": amplitude-tuned modulators are
    positive-only, so W is split by sign, each half run separately and
    subtracted in post-processing (time-domain multiplexing).  The shared
    ``scale`` maps device units back to weight units.
    """
    scale = jnp.maximum(jnp.max(jnp.abs(w)), 1e-6)
    wp = jnp.clip(w, 0.0, None) / scale
    wn = jnp.clip(-w, 0.0, None) / scale
    return wp, wn, scale


def signed_dpe_forward(w: jnp.ndarray, x: jnp.ndarray, dpe: DpeParams,
                       key: jax.Array | None = None) -> jnp.ndarray:
    """Full-range BCM matmul through the positive-only surrogate.

    Runs the positive and negative halves (two chip passes, paper's
    time-multiplexing), subtracts — cancelling the dark offset exactly, as
    the paper notes — and rescales to weight units.
    """
    wp, wn, scale = split_signed(w)
    kp = kn = None
    if key is not None:
        kp, kn = jax.random.split(key)
    yp = dpe_forward(wp, x, dpe, kp)
    yn = dpe_forward(wn, x, dpe, kn)
    return (yp - yn) * scale
