"""Export digitally-recalibrated variants of the trained DPE models.

The hardware-aware-trained weights are exported with BN statistics
calibrated *on the device path* (`{name}_dpe.cpt`) — correct for the
photonic simulator, but the digital / XLA-AOT serving paths then see
mismatched BN stats (paper analogue: you re-run one-shot calibration
whenever the execution substrate changes).  This script loads each trained
bundle, recalibrates BN digitally, and writes `{name}_digital.cpt`.

Runs in seconds (forward passes only).  Invoked by ``make train`` after
``compile.train``; safe to re-run any time.

Usage:  python -m compile.recalib --out ../artifacts
"""

from __future__ import annotations

import argparse
from pathlib import Path

import jax
import jax.numpy as jnp

from . import data as data_mod
from . import export, model
from .train import evaluate, recalibrate_bn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    out = Path(args.out)
    for name in data_mod.DATASETS:
        bundle = out / "models" / f"{name}_dpe.cpt"
        if not bundle.exists():
            print(f"  {name}: not trained yet, skipping")
            continue
        cfgs = model.net_config(name, "circ")
        params, state = model.init_params(jax.random.PRNGKey(0), cfgs)
        tensors = export.read_bundle(bundle)
        for lname in list(params):
            for k in list(params[lname]):
                params[lname][k] = jnp.asarray(tensors[f"{lname}.{k}"])
        for lname in list(state):
            for k in list(state[lname]):
                state[lname][k] = jnp.asarray(tensors[f"{lname}.state.{k}"])
        ds = data_mod.DATASETS[name]()
        state_dig = recalibrate_bn(params, state, cfgs, ds)
        acc, _ = evaluate(params, state_dig, cfgs, ds)
        export.write_bundle(out / "models" / f"{name}_digital.cpt",
                            export.model_tensors(params, state_dig))
        print(f"  {name}: digital-recalibrated acc {acc:.4f} -> "
              f"{name}_digital.cpt")


if __name__ == "__main__":
    main()
