"""Train + export the digital circulant baselines (Fig. 4e config 2).

``compile.train`` exports only the hardware-aware (DPE) bundles for
serving; the digital / XLA-AOT serving paths need the *digitally trained*
circulant weights (you cannot serve device-optimized weights on the
digital path — see compile.recalib docstring).  This re-runs config 2
per dataset with the same seeds as compile.train (so accuracies match
metrics.json) and writes ``{name}_digital.cpt``.

Usage:  python -m compile.train_digital --out ../artifacts [--quick]
"""

from __future__ import annotations

import argparse
from pathlib import Path

from . import data as data_mod
from . import export, model
from .train import evaluate, recalibrate_bn, train_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    out = Path(args.out)
    epochs = 3 if args.quick else 20
    for name in data_mod.DATASETS:
        ds = data_mod.DATASETS[name]()
        cfgs = model.net_config(name, "circ")
        params, state, _ = train_model(ds, cfgs, epochs=epochs,
                                       log=lambda m: None)
        state = recalibrate_bn(params, state, cfgs, ds)
        acc, _ = evaluate(params, state, cfgs, ds)
        export.write_bundle(out / "models" / f"{name}_digital.cpt",
                            export.model_tensors(params, state))
        print(f"  {name}: circ digital acc {acc:.4f} -> {name}_digital.cpt")


if __name__ == "__main__":
    main()
