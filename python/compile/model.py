"""L2: StrC-ONN model definitions (paper Fig. 1a / Fig. 4a).

Functional JAX models over explicit parameter pytrees.  Every conv / FC
layer can be instantiated in two architectures:

* ``gemm`` — ordinary dense weights (the paper's digital baseline);
* ``circ`` — block-circulant weights of order ``l`` stored *compressed* as
  ``(P, Q, l)`` primary vectors (paper Eq. 1), the StrC-ONN configuration.

and executed through two paths:

* ``digital``  — fp32 maths (expansion of the compressed weights);
* ``device``   — the CirPTC transfer chain via a :class:`dpe.DpeParams`
  (sign-split positive-only weights, STE quantization, Γ mixing,
  responsivity tilt, dark offset, dynamic noise).  With the *fitted* Γ̂ this
  is the DPE differentiable mode used for hardware-aware training; with the
  chip's *true* parameters it is the lookup-mode evaluation the paper runs
  on the physical chip (rust/src/simulator mirrors it on the request path).

BN / pooling / activation run digitally, as in the paper ("batch
normalization, pooling, and nonlinear activation are executed on digital
processors").

Convolution uses the im2col identity (paper Fig. 1a): a circulant conv
layer's flattened weight matrix ``(Cout, Cin*k*k)`` is constrained to a BCM
with zero-padded input dimension (the paper's "3 rows of padding" for the
12x4 blur BCM); padded columns meet zero inputs, so dense ``lax.conv`` on
the sliced expansion is exact while training stays fast.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import dpe as dpe_mod
from .kernels import ref

Params = Dict[str, Any]


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# ---------------------------------------------------------------------------
# layer configs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerCfg:
    kind: str                  # conv | fc | bn | relu | pool | flatten
    cin: int = 0
    cout: int = 0
    k: int = 3
    pool: int = 2
    arch: str = "circ"         # circ | gemm  (conv/fc only)
    l: int = 4                 # circulant block order
    act_scale: float = 4.0     # device-domain input scaling (conv/fc only)


def net_config(dataset: str, arch: str, l: int = 4) -> List[LayerCfg]:
    """Network topologies (small VGG-style stacks; DESIGN.md §2 scaling)."""
    conv = lambda ci, co: LayerCfg("conv", cin=ci, cout=co, k=3, arch=arch, l=l)
    fc = lambda ci, co: LayerCfg("fc", cin=ci, cout=co, arch=arch, l=l)
    bn = lambda c: LayerCfg("bn", cin=c)
    relu = LayerCfg("relu")
    pool = LayerCfg("pool")
    flat = LayerCfg("flatten")
    if dataset in ("synth_digits", "synth_textures"):
        # 3x32x32 -> 10 classes (SVHN / CIFAR-10 stand-ins, Fig. 4a)
        return [
            conv(3, 16), bn(16), relu, pool,          # 16x16
            conv(16, 32), bn(32), relu, pool,         # 8x8
            conv(32, 32), bn(32), relu, pool,         # 4x4
            flat,
            fc(32 * 4 * 4, 128), relu,
            fc(128, 10),
        ]
    if dataset == "synth_cxr":
        # 1x64x64 -> 3 classes (COVID-QU-Ex stand-in)
        return [
            conv(1, 8), bn(8), relu, pool,            # 32x32
            conv(8, 16), bn(16), relu, pool,          # 16x16
            conv(16, 32), bn(32), relu, pool,         # 8x8
            flat,
            fc(32 * 8 * 8, 64), relu,
            fc(64, 3),
        ]
    raise ValueError(f"unknown dataset {dataset}")


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_weight(key: jax.Array, cfg: LayerCfg) -> Params:
    """Kaiming-style init in either dense or compressed-circulant form."""
    if cfg.kind == "conv":
        m, n = cfg.cout, cfg.cin * cfg.k * cfg.k
    else:
        m, n = cfg.cout, cfg.cin
    std = float(np.sqrt(2.0 / n))
    if cfg.arch == "circ":
        mp, npad = _ceil_to(m, cfg.l), _ceil_to(n, cfg.l)
        p, q = mp // cfg.l, npad // cfg.l
        w = std * jax.random.normal(key, (p, q, cfg.l))
    else:
        w = std * jax.random.normal(key, (m, n))
    return {"w": w, "b": jnp.zeros(m)}


def init_params(key: jax.Array, cfgs: List[LayerCfg]) -> Tuple[Params, Params]:
    """Returns (params, state): trainables and BN running stats."""
    params: Params = {}
    state: Params = {}
    for i, cfg in enumerate(cfgs):
        name = f"layer{i}"
        if cfg.kind in ("conv", "fc"):
            key, sub = jax.random.split(key)
            params[name] = _init_weight(sub, cfg)
        elif cfg.kind == "bn":
            params[name] = {"gamma": jnp.ones(cfg.cin),
                            "beta": jnp.zeros(cfg.cin)}
            state[name] = {"mean": jnp.zeros(cfg.cin),
                           "var": jnp.ones(cfg.cin)}
    return params, state


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _dense_weight(p: Params, cfg: LayerCfg) -> jnp.ndarray:
    """Full-range dense (m, n) weight for the digital path."""
    if cfg.kind == "conv":
        m, n = cfg.cout, cfg.cin * cfg.k * cfg.k
    else:
        m, n = cfg.cout, cfg.cin
    if cfg.arch == "circ":
        return ref.expand_bcm(p["w"])[:m, :n]
    return p["w"]


def _device_weight(p: Params, cfg: LayerCfg, dpe: dpe_mod.DpeParams
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sign-split device-path effective dense weights (w_pos_eff, w_neg_eff).

    Returned in weight units (scale folded in); subtracting the two conv/fc
    results reproduces the paper's time-multiplexed post-processing and
    cancels the dark offset, which is therefore omitted here.
    """
    if cfg.kind == "conv":
        m, n = cfg.cout, cfg.cin * cfg.k * cfg.k
    else:
        m, n = cfg.cout, cfg.cin
    if cfg.arch == "circ":
        wp, wn, scale = dpe_mod.split_signed(p["w"])
        wpe = dpe_mod.effective_dense_weight(wp, dpe) * scale
        wne = dpe_mod.effective_dense_weight(wn, dpe) * scale
        return wpe[:m, :n], wne[:m, :n]
    # GEMM layers never run on CirPTC in the paper; digital fallback.
    w = p["w"]
    return jnp.clip(w, 0.0, None), jnp.clip(-w, 0.0, None)


def _device_noise(y: jnp.ndarray, dpe: dpe_mod.DpeParams,
                  key: Optional[jax.Array]) -> jnp.ndarray:
    """Dynamic noise injection (paper Fig. 1d).  The two sign-split passes
    each carry independent noise; we inject the summed equivalent
    (factor sqrt(2) on the absolute floor)."""
    if key is None or (dpe.noise_rel == 0.0 and dpe.noise_abs == 0.0):
        return y
    k1, k2 = jax.random.split(key)
    return y + (jnp.abs(lax.stop_gradient(y)) * dpe.noise_rel
                * jax.random.normal(k1, y.shape)
                + dpe.noise_abs * np.sqrt(2.0) * jax.random.normal(k2, y.shape))


def _conv(x: jnp.ndarray, wmat: jnp.ndarray, cfg: LayerCfg) -> jnp.ndarray:
    kern = wmat.reshape(cfg.cout, cfg.cin, cfg.k, cfg.k)
    return lax.conv_general_dilated(
        x, kern, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))


def _linear_layer(x: jnp.ndarray, p: Params, cfg: LayerCfg, mode: str,
                  dpe: Optional[dpe_mod.DpeParams],
                  key: Optional[jax.Array]) -> jnp.ndarray:
    """Shared conv/fc execution across digital and device paths."""
    is_conv = cfg.kind == "conv"
    if mode == "digital" or cfg.arch == "gemm":
        w = _dense_weight(p, cfg)
        y = _conv(x, w, cfg) if is_conv else x @ w.T
    else:
        assert dpe is not None
        s = cfg.act_scale
        xd = jnp.clip(x / s, 0.0, 1.0)
        xd = dpe_mod.ste_quantize(xd, dpe.x_bits) if dpe.x_bits else xd
        wpe, wne = _device_weight(p, cfg, dpe)
        if is_conv:
            y = _conv(xd, wpe, cfg) - _conv(xd, wne, cfg)
        else:
            y = xd @ (wpe - wne).T
        y = _device_noise(y, dpe, key) * s
    b = p["b"]
    return y + (b[None, :, None, None] if is_conv else b[None, :])


def apply(params: Params, state: Params, cfgs: List[LayerCfg],
          x: jnp.ndarray, *, mode: str = "digital",
          dpe: Optional[dpe_mod.DpeParams] = None,
          key: Optional[jax.Array] = None,
          train: bool = False,
          bn_momentum: float = 0.9) -> Tuple[jnp.ndarray, Params]:
    """Run the network.  Returns (logits, new_state)."""
    new_state = dict(state)
    for i, cfg in enumerate(cfgs):
        name = f"layer{i}"
        if cfg.kind in ("conv", "fc"):
            sub = None
            if key is not None:
                key, sub = jax.random.split(key)
            x = _linear_layer(x, params[name], cfg, mode, dpe, sub)
        elif cfg.kind == "bn":
            g, b = params[name]["gamma"], params[name]["beta"]
            if train:
                mean = x.mean(axis=(0, 2, 3))
                var = x.var(axis=(0, 2, 3))
                st = state[name]
                new_state[name] = {
                    "mean": bn_momentum * st["mean"] + (1 - bn_momentum) * mean,
                    "var": bn_momentum * st["var"] + (1 - bn_momentum) * var,
                }
            else:
                mean, var = state[name]["mean"], state[name]["var"]
            x = (x - mean[None, :, None, None]) / jnp.sqrt(
                var[None, :, None, None] + 1e-5)
            x = x * g[None, :, None, None] + b[None, :, None, None]
        elif cfg.kind == "relu":
            x = jax.nn.relu(x)
        elif cfg.kind == "pool":
            x = lax.reduce_window(x, -jnp.inf, lax.max,
                                  (1, 1, cfg.pool, cfg.pool),
                                  (1, 1, cfg.pool, cfg.pool), "VALID")
        elif cfg.kind == "flatten":
            x = x.reshape(x.shape[0], -1)
        else:
            raise ValueError(cfg.kind)
    return x, new_state


# ---------------------------------------------------------------------------
# parameter accounting (paper's 74.91 % reduction claim)
# ---------------------------------------------------------------------------

def count_params(cfgs: List[LayerCfg]) -> Dict[str, float]:
    """Trainable-parameter counts: dense vs compressed weight storage.

    ``gemm``/``circ`` count only conv+FC weight matrices (the quantities the
    paper compresses — also the count of active modulators and weight-memory
    words on CirPTC); ``aux`` counts biases and BN affine parameters, which
    are identical between the two architectures.
    """
    gemm = circ = aux = 0
    for cfg in cfgs:
        if cfg.kind in ("conv", "fc"):
            m = cfg.cout
            n = cfg.cin * cfg.k * cfg.k if cfg.kind == "conv" else cfg.cin
            gemm += m * n
            mp, npad = _ceil_to(m, cfg.l), _ceil_to(n, cfg.l)
            circ += (mp // cfg.l) * (npad // cfg.l) * cfg.l
            aux += m
        elif cfg.kind == "bn":
            aux += 2 * cfg.cin
    return {"gemm": gemm, "circ": circ, "aux": aux,
            "reduction_pct": 100.0 * (1.0 - circ / max(gemm, 1))}
