"""L1 Pallas kernel: block-circulant matmul (the CirPTC compute hot-spot).

TPU mapping of the paper's photonic WDM fan-out (DESIGN.md §3): the kernel
reads only the *compressed* ``(P, Q, l)`` primary vectors from HBM — an
``l``-fold reduction in weight traffic, the memory-side analogue of the
paper's ``l``-fold reduction in active modulators — expands each circulant
block to dense form *inside VMEM* with an iota-based gather, and feeds the
MXU with one ``(l, N) @ (N, Bt)`` matmul per grid step.

The grid is ``(P, B / Bt)``: one program instance per block-row of the BCM
per batch tile, mirroring how each CirPTC output column's photodiode sums a
full row of the crossbar per clock cycle.

Kernels are lowered with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); see DESIGN.md §8 for the real-TPU VMEM/MXU estimate.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _expand_rows(wb: jnp.ndarray, l: int) -> jnp.ndarray:
    """(Q, l) primary vectors -> (l, Q*l) dense block-row of the BCM.

    Uses broadcasted iota (TPU-friendly: no 1-D iota) to build the circulant
    gather table ``idx[r, c] = (c - r) mod l`` from paper Eq. (1), then
    one-hot matmul instead of dynamic gather — MXU-mappable and supported in
    both interpret and compiled modes.
    """
    q = wb.shape[0]
    rows = lax.broadcasted_iota(jnp.int32, (l, l), 0)
    cols = lax.broadcasted_iota(jnp.int32, (l, l), 1)
    idx = (cols - rows) % l                          # (l, l)
    # one-hot over the source index: onehot[r, c, s] = (idx[r,c] == s)
    src = lax.broadcasted_iota(jnp.int32, (l, l, l), 2)
    onehot = (idx[:, :, None] == src).astype(wb.dtype)
    # expanded[q, r, c] = sum_s onehot[r, c, s] * wb[q, s]
    expanded = jnp.einsum("rcs,qs->qrc", onehot, wb)
    # block-row layout: rows r, concatenated over q on the column axis
    return expanded.transpose(1, 0, 2).reshape(l, q * l)


def _bcm_kernel(w_ref, x_ref, o_ref, *, l: int):
    """One (block-row p, batch-tile b) program instance."""
    wb = w_ref[0]                                    # (Q, l) primary vectors
    row = _expand_rows(wb, l)                        # (l, Q*l) in VMEM
    o_ref[...] = jnp.dot(row, x_ref[...], preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("batch_tile", "interpret"))
def bcm_matmul(w: jnp.ndarray, x: jnp.ndarray, *, batch_tile: int = 0,
               interpret: bool = True) -> jnp.ndarray:
    """Block-circulant matmul ``y = expand(w) @ x`` via Pallas.

    Args:
      w: ``(P, Q, l)`` compressed BCM (primary row vectors, paper Eq. 1).
      x: ``(Q*l, B)`` input batch.
      batch_tile: batch tile width ``Bt`` (0 = whole batch in one tile).
      interpret: run the kernel in interpret mode (required on CPU PJRT).

    Returns:
      ``(P*l, B)`` output.
    """
    p, q, l = w.shape
    n, b = x.shape
    assert n == q * l, f"x rows {n} != Q*l {q * l}"
    bt = batch_tile if batch_tile and b % batch_tile == 0 else b
    grid = (p, b // bt)
    return pl.pallas_call(
        functools.partial(_bcm_kernel, l=l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, l), lambda i, j: (i, 0, 0)),   # compressed row p
            pl.BlockSpec((n, bt), lambda i, j: (0, j)),        # batch tile
        ],
        out_specs=pl.BlockSpec((l, bt), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((p * l, b), x.dtype),
        interpret=interpret,
    )(w, x)


def _bcm_fft_kernel(fw_re_ref, fw_im_ref, x_ref, o_ref, *, l: int):
    """FFT-domain variant (paper Eq. 2): weights arrive pre-transformed.

    The host passes ``FFT(first-column)`` split into re/im planes (PJRT CPU
    handles complex, but real planes keep the artifact dtype-uniform).  The
    kernel does the per-block spectral product and inverse DFT via two real
    matmuls against precomputed DFT bases — all MXU-shaped.
    """
    qsize = x_ref.shape[0] // l
    k = lax.broadcasted_iota(jnp.float32, (l, l), 0)
    nn = lax.broadcasted_iota(jnp.float32, (l, l), 1)
    ang = 2.0 * jnp.pi * k * nn / l
    dft_re, dft_im = jnp.cos(ang), -jnp.sin(ang)
    xb = x_ref[...].reshape(qsize, l, -1)
    fx_re = jnp.einsum("kn,qnb->qkb", dft_re, xb)
    fx_im = jnp.einsum("kn,qnb->qkb", dft_im, xb)
    fw_re, fw_im = fw_re_ref[0], fw_im_ref[0]        # (Q, l)
    fy_re = jnp.einsum("qk,qkb->kb", fw_re, fx_re) - jnp.einsum(
        "qk,qkb->kb", fw_im, fx_im)
    fy_im = jnp.einsum("qk,qkb->kb", fw_re, fx_im) + jnp.einsum(
        "qk,qkb->kb", fw_im, fx_re)
    # inverse DFT, real part: y[n] = (1/l) sum_k re(F[k] e^{+i 2pi kn/l})
    y = (jnp.einsum("kn,kb->nb", dft_re, fy_re) +
         jnp.einsum("kn,kb->nb", dft_im, fy_im)) / l
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bcm_matmul_fft(w: jnp.ndarray, x: jnp.ndarray, *,
                   interpret: bool = True) -> jnp.ndarray:
    """FFT-path block-circulant matmul (paper Eq. 2) as a Pallas kernel.

    Pre-transforms the compressed weights on the host side of the trace
    (fused into the same HLO), then runs the spectral kernel per block-row.
    """
    p, q, l = w.shape
    n, b = x.shape
    assert n == q * l
    col = jnp.roll(w[:, :, ::-1], 1, axis=-1)        # first columns
    fw = jnp.fft.fft(col, axis=-1)
    fw_re = jnp.real(fw).astype(x.dtype)
    fw_im = jnp.imag(fw).astype(x.dtype)
    return pl.pallas_call(
        functools.partial(_bcm_fft_kernel, l=l),
        grid=(p,),
        in_specs=[
            pl.BlockSpec((1, q, l), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q, l), lambda i: (i, 0, 0)),
            pl.BlockSpec((n, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((l, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((p * l, b), x.dtype),
        interpret=interpret,
    )(fw_re, fw_im, x)
