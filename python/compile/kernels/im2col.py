"""L1 Pallas kernel: im2col patch extraction (paper Fig. 1a).

Transforms a ``(C, H, W)`` image into the ``(C*k*k, OH*OW)`` patch matrix
that turns convolution into the BCM matmuls CirPTC executes.  The grid is
``(OH,)`` — one program instance per output row, the unit at which the
paper's FPGA streams sliding-window vectors to the chip.

Stride 1 only (all kernels in the paper's networks are stride-1; pooling
provides downsampling).  ``k`` and ``C`` are static, so the gather unrolls
into ``C*k*k`` dynamic row slices; windows overlap between grid steps, so
the image is kept whole in VMEM and sliced with ``program_id``-relative
dynamic slices rather than a non-overlapping BlockSpec.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _im2col_kernel(img_ref, o_ref, *, c: int, k: int, ow: int):
    i = pl.program_id(0)                              # output row index
    img = img_ref[...]                                # (C, H, W) in VMEM
    for ci in range(c):
        for di in range(k):
            for dj in range(k):
                row = ci * k * k + di * k + dj
                sl = lax.dynamic_slice(img, (ci, i + di, dj), (1, 1, ow))
                o_ref[row, :] = sl.reshape(ow)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def im2col(img: jnp.ndarray, k: int, *, interpret: bool = True) -> jnp.ndarray:
    """Pallas im2col: ``(C, H, W) -> (C*k*k, (H-k+1)*(W-k+1))``, stride 1."""
    c, h, w = img.shape
    oh, ow = h - k + 1, w - k + 1
    return pl.pallas_call(
        functools.partial(_im2col_kernel, c=c, k=k, ow=ow),
        grid=(oh,),
        in_specs=[pl.BlockSpec((c, h, w), lambda i: (0, 0, 0))],
        out_specs=pl.BlockSpec((c * k * k, ow), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((c * k * k, oh * ow), img.dtype),
        interpret=interpret,
    )(img)
