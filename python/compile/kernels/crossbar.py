"""L1 Pallas kernel: photonic crossbar forward (CirPTC with nonidealities).

This is the device-faithful variant of ``circulant.bcm_matmul``: it applies
the deterministic parts of the CirPTC transfer chain *inside* the kernel —
DAC quantization of inputs (4-bit) and weights (6-bit), spectral-crosstalk
mixing ``Gamma`` over the ``l`` WDM channels of each block (paper Methods,
Eq. 5), and the photodiode dark-current offset — so the AOT artifact that
the rust coordinator serves already models the chip, matching the paper's
"lookup mode" inference without a python round-trip.

Stochastic noise (shot/thermal, fabrication variance) is injected by the
rust simulator on top of this deterministic graph; keeping the artifact
deterministic makes it reproducible and cacheable.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .circulant import _expand_rows


def _quantize(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    levels = float((1 << bits) - 1)
    return jnp.round(jnp.clip(x, 0.0, 1.0) * levels) / levels


def _crossbar_kernel(w_ref, x_ref, gamma_ref, o_ref, *, l: int,
                     w_bits: int, x_bits: int, dark: float):
    wb = w_ref[0]                                    # (Q, l)
    x = x_ref[...]                                   # (Q*l, Bt)
    if x_bits:
        x = _quantize(x, x_bits)
    if w_bits:
        wb = _quantize(wb, w_bits)
    # spectral crosstalk: mix the l WDM channels within each input block
    qsize = x.shape[0] // l
    xb = x.reshape(qsize, l, -1)
    xb = jnp.einsum("ij,qjb->qib", gamma_ref[...], xb)
    x = xb.reshape(qsize * l, -1)
    row = _expand_rows(wb, l)                        # (l, Q*l)
    y = jnp.dot(row, x, preferred_element_type=o_ref.dtype)
    o_ref[...] = y + jnp.asarray(dark, o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "w_bits", "x_bits", "dark", "batch_tile", "interpret"))
def crossbar_forward(w: jnp.ndarray, x: jnp.ndarray, gamma: jnp.ndarray, *,
                     w_bits: int = 6, x_bits: int = 4, dark: float = 0.0,
                     batch_tile: int = 0, interpret: bool = True) -> jnp.ndarray:
    """Deterministic CirPTC forward for one BCM.

    Args:
      w: ``(P, Q, l)`` compressed weights in ``[0, 1]`` (device domain).
      x: ``(Q*l, B)`` inputs in ``[0, 1]``.
      gamma: ``(l, l)`` spectral-crosstalk mixing matrix (row-normalised).
      w_bits / x_bits: DAC resolutions (paper: 6-bit weights, 4-bit inputs);
        0 disables quantization.
      dark: photodiode dark-current offset added to every output.

    Returns:
      ``(P*l, B)`` photocurrents (arbitrary units, pre-TIA).
    """
    p, q, l = w.shape
    n, b = x.shape
    assert n == q * l and gamma.shape == (l, l)
    bt = batch_tile if batch_tile and b % batch_tile == 0 else b
    return pl.pallas_call(
        functools.partial(_crossbar_kernel, l=l, w_bits=w_bits,
                          x_bits=x_bits, dark=dark),
        grid=(p, b // bt),
        in_specs=[
            pl.BlockSpec((1, q, l), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((n, bt), lambda i, j: (0, j)),
            pl.BlockSpec((l, l), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((l, bt), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((p * l, b), x.dtype),
        interpret=interpret,
    )(w, x, gamma)
