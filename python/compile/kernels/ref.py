"""Pure-jnp reference oracles for the Pallas kernels.

Everything in this file is the *specification*: slow, obviously-correct
implementations of the block-circulant algebra (paper Eq. 1/2), im2col
(paper Fig. 1a), and the photonic crossbar transfer chain (paper Fig. 2 d-f).
The Pallas kernels in this package and the rust simulator
(rust/src/simulator/) are both validated against these functions.

Conventions
-----------
A block-circulant matrix (BCM) ``W`` of shape ``(M, N)`` with block order
``l`` is stored compressed as ``w`` of shape ``(P, Q, l)`` with
``M = P*l``, ``N = Q*l``.  ``w[p, q]`` is the *primary vector* (first row)
of circulant block ``W_pq``; following paper Eq. (1),

    W_pq[r, c] = w[p, q, (c - r) mod l]
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# circulant algebra
# ---------------------------------------------------------------------------

def circulant_indices(l: int) -> np.ndarray:
    """(l, l) gather table: ``idx[r, c] = (c - r) mod l`` (paper Eq. 1)."""
    r = np.arange(l)[:, None]
    c = np.arange(l)[None, :]
    return (c - r) % l


def expand_circulant(w_row: jnp.ndarray) -> jnp.ndarray:
    """Expand a primary vector (..., l) into full (..., l, l) circulant blocks."""
    l = w_row.shape[-1]
    return w_row[..., circulant_indices(l)]


def expand_bcm(w: jnp.ndarray) -> jnp.ndarray:
    """Expand compressed (P, Q, l) weights into the dense (P*l, Q*l) BCM."""
    p, q, l = w.shape
    blocks = expand_circulant(w)                     # (P, Q, l, l)
    return blocks.transpose(0, 2, 1, 3).reshape(p * l, q * l)


def bcm_matmul_ref(w: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Dense-expansion reference: ``y = expand(w) @ x``.

    w: (P, Q, l) compressed BCM;  x: (N, B) column-major batch;  y: (M, B).
    """
    return expand_bcm(w) @ x


def bcm_matmul_fft_ref(w: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """FFT reference (paper Eq. 2), generalised to blocks.

    For a circulant block with primary *row* ``w``, the first *column* is
    ``w[(-r) mod l]``, i.e. ``roll(flip(w), 1)``; circulant matmul is then
    ``IFFT(FFT(col) * FFT(x))`` applied per (p, q) block and summed over q.
    """
    p, q, l = w.shape
    b = x.shape[1]
    xb = x.reshape(q, l, b)
    col = jnp.roll(w[:, :, ::-1], 1, axis=-1)        # (P, Q, l) first columns
    fw = jnp.fft.fft(col, axis=-1)                   # (P, Q, l)
    fx = jnp.fft.fft(xb, axis=1)                     # (Q, l, B)
    fy = jnp.einsum("pql,qlb->plb", fw, fx)
    y = jnp.fft.ifft(fy, axis=1).real
    return y.reshape(p * l, b).astype(x.dtype)


# ---------------------------------------------------------------------------
# im2col / convolution
# ---------------------------------------------------------------------------

def im2col_ref(img: jnp.ndarray, k: int, stride: int = 1) -> jnp.ndarray:
    """(C, H, W) image -> (C*k*k, n_patches) patch matrix (paper Fig. 1a).

    Patch columns are ordered row-major over output positions; within a
    column the layout is channel-major then kernel-row then kernel-col,
    matching the row-wise flattening of kernels into the weight matrix.
    """
    c, h, w = img.shape
    oh = (h - k) // stride + 1
    ow = (w - k) // stride + 1
    cols = []
    for i in range(oh):
        for j in range(ow):
            patch = img[:, i * stride:i * stride + k, j * stride:j * stride + k]
            cols.append(patch.reshape(-1))
    return jnp.stack(cols, axis=1)                   # (C*k*k, oh*ow)


def conv2d_ref(img: jnp.ndarray, kern: jnp.ndarray, stride: int = 1) -> jnp.ndarray:
    """Naive conv: img (C, H, W), kern (Cout, C, k, k) -> (Cout, OH, OW)."""
    cout, c, k, _ = kern.shape
    _, h, w = img.shape
    oh = (h - k) // stride + 1
    ow = (w - k) // stride + 1
    wmat = kern.reshape(cout, c * k * k)
    xmat = im2col_ref(img, k, stride)
    return (wmat @ xmat).reshape(cout, oh, ow)


# ---------------------------------------------------------------------------
# photonic transfer chain (mirrors rust/src/photonic/)
# ---------------------------------------------------------------------------

def mzm_transmission(v: jnp.ndarray, v_pi: float = 1.0) -> jnp.ndarray:
    """Thermo-optic MZM amplitude-tuning intensity transfer.

    Push-pull MZM biased at null: T(v) = sin^2(pi * v / (2 * v_pi)).
    Encoding maps x in [0, 1] to v = (2 v_pi / pi) asin(sqrt(x)), so an
    *ideal* device round-trips exactly; nonideality enters via quantized
    drive voltages and finite extinction.
    """
    return jnp.sin(jnp.pi * v / (2.0 * v_pi)) ** 2


def mzm_drive(x: jnp.ndarray, v_pi: float = 1.0) -> jnp.ndarray:
    """Inverse of :func:`mzm_transmission` for x in [0, 1]."""
    return (2.0 * v_pi / jnp.pi) * jnp.arcsin(jnp.sqrt(jnp.clip(x, 0.0, 1.0)))


def mrr_drop_transmission(delta: jnp.ndarray, fwhm: float = 1.0,
                          peak: float = 1.0) -> jnp.ndarray:
    """Add-drop MRR drop-port Lorentzian: T(delta) = peak / (1 + (2 delta/fwhm)^2).

    ``delta`` is the detuning from resonance in the same units as ``fwhm``.
    Weight encoding detunes the ring thermally; the usable branch is
    monotonic (paper Fig. 2f uses one branch per ring to avoid overlap).
    """
    return peak / (1.0 + (2.0 * delta / fwhm) ** 2)


def mrr_weight_detuning(w: jnp.ndarray, fwhm: float = 1.0,
                        peak: float = 1.0) -> jnp.ndarray:
    """Inverse of the drop-port Lorentzian on the left branch: w -> delta <= 0."""
    w = jnp.clip(w, 1e-6, peak)
    return -0.5 * fwhm * jnp.sqrt(peak / w - 1.0)


def crosstalk_matrix(n: int, eps: float) -> jnp.ndarray:
    """Inter-channel spectral-leakage mixing Gamma (paper Methods, Eq. 5).

    Adjacent WDM channels leak a fraction ``eps``; next-adjacent eps^2, etc.
    Rows are renormalised so a calibrated all-ones input maps to one.
    """
    i = np.arange(n)[:, None]
    j = np.arange(n)[None, :]
    g = eps ** np.abs(i - j).astype(np.float64)
    g = g / g.sum(axis=1, keepdims=True)
    return jnp.asarray(g, dtype=jnp.float32)


def quantize_ref(x: jnp.ndarray, bits: int, lo: float = 0.0,
                 hi: float = 1.0) -> jnp.ndarray:
    """Uniform affine quantization to 2^bits levels over [lo, hi]."""
    levels = (1 << bits) - 1
    xq = jnp.round((jnp.clip(x, lo, hi) - lo) / (hi - lo) * levels)
    return xq / levels * (hi - lo) + lo


def crossbar_forward_ref(w: jnp.ndarray, x: jnp.ndarray, *,
                         eps: float = 0.0,
                         w_bits: int = 0,
                         x_bits: int = 0,
                         dark: float = 0.0) -> jnp.ndarray:
    """Ideal-physics CirPTC forward for one BCM (no stochastic noise).

    w: (P, Q, l) compressed weights in [0, 1];  x: (N, B) inputs in [0, 1].
    Chain: quantize -> (MZM / MRR encode+decode are calibrated inverses,
    so the deterministic nonideality is quantization in the *device* domain)
    -> crosstalk mixing Gamma over the l WDM channels of each block column
    -> crossbar matmul -> PD dark-current offset.
    """
    p, q, l = w.shape
    if x_bits:
        x = quantize_ref(x, x_bits)
    if w_bits:
        w = quantize_ref(w, w_bits)
    if eps > 0.0:
        gamma = crosstalk_matrix(l, eps)
        xb = x.reshape(q, l, -1)
        x = jnp.einsum("ij,qjb->qib", gamma, xb).reshape(q * l, -1)
    y = bcm_matmul_ref(w, x)
    return y + dark
