"""Tensor/manifest export: the python→rust weight interchange.

No serde/npz on the rust side (offline vendor set), so we define a tiny
binary tensor-bundle format, ``CPT1``, implemented symmetrically here and
in ``rust/src/data/bundle.rs``:

    magic   b"CPT1"
    u32     n_tensors
    repeat n_tensors:
        u32     name_len;  name bytes (utf-8)
        u8      dtype      (0 = f32, 1 = i32)
        u8      ndim
        u32[n]  dims
        bytes   data (little-endian, C order)

plus a JSON manifest per model describing layer configs (parsed by the
hand-rolled JSON reader in ``rust/src/util/json.rs``).
"""

from __future__ import annotations

import json
import struct
from pathlib import Path
from typing import Dict, List

import numpy as np

MAGIC = b"CPT1"
_DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}


def write_bundle(path: str | Path, tensors: Dict[str, np.ndarray]) -> None:
    """Write a named-tensor bundle in CPT1 format (sorted by name)."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name in sorted(tensors):
            arr = np.ascontiguousarray(tensors[name])
            if arr.dtype not in _DTYPES:
                arr = arr.astype(np.float32)
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", _DTYPES[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def read_bundle(path: str | Path) -> Dict[str, np.ndarray]:
    """Read a CPT1 bundle (round-trip check for :func:`write_bundle`)."""
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, "bad magic"
        (n,) = struct.unpack("<I", f.read(4))
        out = {}
        for _ in range(n):
            (nl,) = struct.unpack("<I", f.read(4))
            name = f.read(nl).decode()
            dt, nd = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{nd}I", f.read(4 * nd)) if nd else ()
            dtype = np.float32 if dt == 0 else np.int32
            count = int(np.prod(dims)) if dims else 1
            data = np.frombuffer(f.read(count * 4), dtype=dtype)
            out[name] = data.reshape(dims).copy()
        return out


def model_tensors(params: dict, state: dict) -> Dict[str, np.ndarray]:
    """Flatten model params/state pytrees into bundle names."""
    out: Dict[str, np.ndarray] = {}
    for lname, p in params.items():
        for k, v in p.items():
            out[f"{lname}.{k}"] = np.asarray(v)
    for lname, s in state.items():
        for k, v in s.items():
            out[f"{lname}.state.{k}"] = np.asarray(v)
    return out


def write_manifest(path: str | Path, cfgs: List, meta: dict) -> None:
    """JSON manifest of the layer stack + metadata for the rust engine."""
    layers = []
    for cfg in cfgs:
        layers.append({
            "kind": cfg.kind, "cin": cfg.cin, "cout": cfg.cout, "k": cfg.k,
            "pool": cfg.pool, "arch": cfg.arch, "l": cfg.l,
            "act_scale": cfg.act_scale,
        })
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps({"layers": layers, **meta}, indent=1))
