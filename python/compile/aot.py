"""AOT lowering: jax → HLO *text* → ``artifacts/*.hlo.txt``.

The interchange format is HLO text, not ``.serialize()``: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifacts produced (all loaded by ``rust/src/runtime``):

* ``bcm_{M}x{N}_b{B}.hlo.txt``      — Pallas block-circulant matmul kernel
  (compressed (P,Q,l) weights + (N,B) inputs as parameters).
* ``crossbar_{M}x{N}_b{B}.hlo.txt`` — deterministic CirPTC forward (4/6-bit
  quantization + Γ crosstalk + dark), the lookup-mode serving graph.
* ``gemm_{M}x{N}_b{B}.hlo.txt``     — dense matmul baseline.
* ``model_{dataset}.hlo.txt``       — full StrC-ONN digital inference graph
  with trained weights baked in (random-init fallback before training has
  run, so ``make artifacts`` works from a clean tree).
* ``model_{dataset}_chip.hlo.txt``  — same network through the
  deterministic device path (true Γ, quantization, tilt; noise is added by
  the rust simulator on top — artifacts stay reproducible).

Python runs ONLY here (build time); the rust binary is self-contained
afterwards.  Usage: ``python -m compile.aot --out ../artifacts``.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import chip as chip_mod
from . import data as data_mod
from . import export, model
from .kernels import ref
from .kernels.circulant import bcm_matmul
from .kernels.crossbar import crossbar_forward
from .train import true_dpe_from_chip


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple for rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants: baked model weights must survive the text
    # round-trip (the default elides them as '{...}', which the rust-side
    # parser would reject or silently zero).
    return comp.as_hlo_text(print_large_constants=True)


def _write(out: Path, name: str, text: str) -> None:
    path = out / f"{name}.hlo.txt"
    path.write_text(text)
    print(f"  wrote {path} ({len(text)} chars)")


# ---------------------------------------------------------------------------
# kernel artifacts
# ---------------------------------------------------------------------------

BCM_SIZES = [
    # (P, Q, l, B)
    (4, 4, 4, 8),        # 16x16  — the fabricated order-4 prototype scaled
    (12, 12, 4, 16),     # 48x48  — the paper's peak-efficiency size
    (16, 16, 4, 16),     # 64x64  — past the laser-power knee (Fig. S16)
]


def export_kernels(out: Path) -> None:
    gamma = ref.crosstalk_matrix(4, chip_mod.ChipParams().eps)
    for (p, q, l, b) in BCM_SIZES:
        m, n = p * l, q * l
        wspec = jax.ShapeDtypeStruct((p, q, l), jnp.float32)
        xspec = jax.ShapeDtypeStruct((n, b), jnp.float32)

        fn = lambda w, x: (bcm_matmul(w, x),)
        _write(out, f"bcm_{m}x{n}_b{b}",
               to_hlo_text(jax.jit(fn).lower(wspec, xspec)))

        cb = lambda w, x: (crossbar_forward(
            w, x, gamma, dark=chip_mod.ChipParams().dark),)
        _write(out, f"crossbar_{m}x{n}_b{b}",
               to_hlo_text(jax.jit(cb).lower(wspec, xspec)))

        dspec = jax.ShapeDtypeStruct((m, n), jnp.float32)
        ge = lambda w, x: (w @ x,)
        _write(out, f"gemm_{m}x{n}_b{b}",
               to_hlo_text(jax.jit(ge).lower(dspec, xspec)))


# ---------------------------------------------------------------------------
# model artifacts
# ---------------------------------------------------------------------------

def _load_or_init(out: Path, name: str, cfgs, variant: str = "dpe"):
    """Trained weights if train.py has run, else deterministic random init.

    variant "digital" -> the digitally-trained circulant baseline
    (train_digital.py); "dpe" -> the hardware-aware-trained model whose BN
    stats are device-calibrated.  The digital inference graph must carry
    the former, the chip graph the latter (compile.recalib docstring).
    """
    bundle = out / "models" / f"{name}_{variant}.cpt"
    params, state = model.init_params(jax.random.PRNGKey(0), cfgs)
    if bundle.exists():
        tensors = export.read_bundle(bundle)
        for lname in list(params):
            for k in list(params[lname]):
                params[lname][k] = jnp.asarray(tensors[f"{lname}.{k}"])
        for lname in list(state):
            for k in list(state[lname]):
                state[lname][k] = jnp.asarray(tensors[f"{lname}.state.{k}"])
        src = f"trained ({variant})"
    else:
        src = "random-init"
    print(f"  model {name}: {src} weights")
    return params, state


def export_models(out: Path, batch: int = 8) -> None:
    chp = chip_mod.make_chip(chip_mod.ChipParams())
    dpe_det = true_dpe_from_chip(chp, noisy=False)
    for name in data_mod.DATASETS:
        cfgs = model.net_config(name, "circ")
        c, h = (3, 32) if name != "synth_cxr" else (1, 64)
        xspec = jax.ShapeDtypeStruct((batch, c, h, h), jnp.float32)

        params, state = _load_or_init(out, name, cfgs, "digital")
        dig = lambda x: (model.apply(params, state, cfgs, x,
                                     mode="digital", train=False)[0],)
        _write(out, f"model_{name}", to_hlo_text(jax.jit(dig).lower(xspec)))

        params, state = _load_or_init(out, name, cfgs, "dpe")
        chipf = lambda x: (model.apply(params, state, cfgs, x, mode="device",
                                       dpe=dpe_det, train=False)[0],)
        _write(out, f"model_{name}_chip",
               to_hlo_text(jax.jit(chipf).lower(xspec)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--skip-models", action="store_true")
    args = ap.parse_args()
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    export_kernels(out)
    if not args.skip_models:
        export_models(out)

    # chip description for the rust simulator (idempotent with train.py)
    chp = chip_mod.make_chip(chip_mod.ChipParams())
    (out / "chip.json").write_text(json.dumps(chp.export_dict(), indent=1))
    # manifest of everything produced
    manifest = sorted(p.name for p in out.glob("*.hlo.txt"))
    (out / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"done: {len(manifest)} HLO artifacts in {out}")


if __name__ == "__main__":
    main()
