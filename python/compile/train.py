"""Hardware-aware training framework (paper Fig. 1d + Methods).

Runs the four Fig. 4e configurations per dataset:

  1. ``gemm``        — dense fp32 digital baseline
  2. ``circ``        — block-circulant (order-4) digital fp32
  3. ``circ→chip``   — config 2 deployed on the chip *without* DPE training
  4. ``circ+dpe``    — hardware-aware training: calibration sweep → Γ̂ fit →
                       differentiable-mode training with quantization + noise
                       injection → lookup-mode (true-chip) evaluation

and exports per-dataset metrics JSON, trained weight bundles (CPT1), the
chip description, and golden vectors for rust cross-validation.

Optimizer is a hand-rolled Adam (no optax needed); everything jit-compiles
once per (dataset, config).

Usage:  python -m compile.train --out ../artifacts [--quick]
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import chip as chip_mod
from . import data as data_mod
from . import dpe as dpe_mod
from . import export, model


# ---------------------------------------------------------------------------
# Adam
# ---------------------------------------------------------------------------

def adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(grads, opt, params, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = opt["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                               opt["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                               opt["v"], grads)
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1 ** t), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2 ** t), v)
    new = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mh, vh)
    return new, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# training / evaluation
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def train_model(ds, cfgs, *, mode="digital", dpe=None, epochs=20,
                batch=128, lr=3e-3, seed=0, log=print):
    """Train one configuration; returns (params, state, history)."""
    key = jax.random.PRNGKey(seed)
    key, kinit = jax.random.split(key)
    params, state = model.init_params(kinit, cfgs)
    opt = adam_init(params)
    xtr = jnp.asarray(ds["train_x"])
    ytr = jnp.asarray(ds["train_y"])
    n = xtr.shape[0]
    steps = n // batch

    def loss_fn(p, st, xb, yb, k):
        logits, st2 = model.apply(p, st, cfgs, xb, mode=mode, dpe=dpe,
                                  key=k, train=True)
        return cross_entropy(logits, yb), st2

    @jax.jit
    def step(p, st, o, xb, yb, k):
        (loss, st2), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            p, st, xb, yb, k)
        p2, o2 = adam_update(grads, o, p, lr=lr)
        return p2, st2, o2, loss

    hist = []
    for ep in range(epochs):
        key, kperm = jax.random.split(key)
        perm = jax.random.permutation(kperm, n)
        losses = []
        for s in range(steps):
            idx = perm[s * batch:(s + 1) * batch]
            key, kstep = jax.random.split(key)
            params, state, opt, loss = step(
                params, state, opt, xtr[idx], ytr[idx], kstep)
            losses.append(float(loss))
        hist.append(float(np.mean(losses)))
        log(f"    epoch {ep + 1}/{epochs}  loss {hist[-1]:.4f}")
    return params, state, hist


def recalibrate_bn(params, state, cfgs, ds, *, mode="digital", dpe=None,
                   batch=128, seed=99):
    """Recompute BN running stats exactly with the final weights.

    With few training steps the EMA (momentum 0.9) is still dominated by its
    zero/one initialisation, wrecking eval accuracy; the standard fix is a
    calibration pass: average per-batch statistics over the training set.
    Also re-run whenever the *execution path* changes (e.g. evaluating
    digitally-trained weights on the chip), mirroring the paper's one-shot
    calibration of the physical chip.
    """
    xtr = jnp.asarray(ds["train_x"])
    key = jax.random.PRNGKey(seed)

    @jax.jit
    def batch_stats(xb, k):
        # momentum 0 => returned state holds the raw batch statistics
        _, st = model.apply(params, state, cfgs, xb, mode=mode, dpe=dpe,
                            key=k, train=True, bn_momentum=0.0)
        return st

    acc = None
    nb = 0
    for s in range(0, xtr.shape[0] - batch + 1, batch):
        key, k = jax.random.split(key)
        st = batch_stats(xtr[s:s + batch], k)
        if acc is None:
            acc = st
        else:
            acc = jax.tree_util.tree_map(lambda a, b: a + b, acc, st)
        nb += 1
    return jax.tree_util.tree_map(lambda a: a / nb, acc)


def evaluate(params, state, cfgs, ds, *, mode="digital", dpe=None,
             seed=123, batch=128):
    """Accuracy + confusion matrix on the test split."""
    xte = jnp.asarray(ds["test_x"])
    yte = np.asarray(ds["test_y"])
    nclass = ds["classes"]
    key = jax.random.PRNGKey(seed)
    preds = []

    @jax.jit
    def fwd(xb, k):
        logits, _ = model.apply(params, state, cfgs, xb, mode=mode,
                                dpe=dpe, key=k, train=False)
        return jnp.argmax(logits, axis=1)

    for s in range(0, xte.shape[0], batch):
        key, k = jax.random.split(key)
        preds.append(np.asarray(fwd(xte[s:s + batch], k)))
    preds = np.concatenate(preds)
    acc = float((preds == yte).mean())
    conf = np.zeros((nclass, nclass), np.int32)
    for t, p in zip(yte, preds):
        conf[t, p] += 1
    return acc, conf


def sens_spec(conf: np.ndarray, cls: int):
    """Sensitivity / specificity for one class (paper: COVID-19 class)."""
    tp = conf[cls, cls]
    fn = conf[cls].sum() - tp
    fp = conf[:, cls].sum() - tp
    tn = conf.sum() - tp - fn - fp
    return tp / max(tp + fn, 1), tn / max(tn + fp, 1)


# ---------------------------------------------------------------------------
# experiment driver
# ---------------------------------------------------------------------------

def true_dpe_from_chip(chp: chip_mod.PhotonicChip,
                       noisy: bool = True) -> dpe_mod.DpeParams:
    """DpeParams carrying the chip's *true* nonidealities (lookup-mode eval)."""
    p = chp.p
    return dpe_mod.DpeParams(
        l=p.l, gamma_hat=chp.gamma_true,
        dark_hat=jnp.full((p.l,), p.dark), resp_hat=chp.resp,
        w_bits=p.w_bits, x_bits=p.x_bits,
        noise_rel=p.sigma_rel if noisy else 0.0,
        noise_abs=p.sigma_abs if noisy else 0.0)


def fitted_dpe_from_chip(chp: chip_mod.PhotonicChip, key,
                         n_sweep: int = 192) -> dpe_mod.DpeParams:
    """Calibration sweep → LUT → Γ̂ least-squares fit (paper Eq. 5)."""
    lut = chp.sweep_lut(key, n_sweep=n_sweep)
    gamma_hat, dark_hat, resp = chp.fit_gamma(lut)
    p = chp.p
    # The lstsq absorbs the responsivity tilt into Γ̂ (it observes only the
    # product), so the fitted estimator uses resp=1 — same as the paper's
    # Y'(w,x) = W·Γx with a single mixing operator.
    return dpe_mod.DpeParams(
        l=p.l, gamma_hat=gamma_hat, dark_hat=dark_hat,
        resp_hat=jnp.ones(p.l), w_bits=p.w_bits, x_bits=p.x_bits,
        noise_rel=p.sigma_rel, noise_abs=p.sigma_abs)


def run_dataset(name: str, out: Path, quick: bool, log=print) -> dict:
    epochs = 3 if quick else 20
    sizes = dict(n_train=512, n_test=256) if quick else {}
    ds = data_mod.DATASETS[name](**sizes)
    chp = chip_mod.make_chip(chip_mod.ChipParams())
    key = jax.random.PRNGKey(42)

    res = {"dataset": name, "classes": ds["classes"]}
    t0 = time.time()

    # -- 1. dense GEMM digital baseline -----------------------------------
    log(f"  [{name}] config 1/4: GEMM digital fp32")
    cfg_g = model.net_config(name, "gemm")
    pg, sg, _ = train_model(ds, cfg_g, epochs=epochs, log=log)
    sg = recalibrate_bn(pg, sg, cfg_g, ds)
    acc_g, conf_g = evaluate(pg, sg, cfg_g, ds)
    res["acc_gemm_digital"] = acc_g

    # -- 2. circulant digital ---------------------------------------------
    log(f"  [{name}] config 2/4: circulant digital fp32")
    cfg_c = model.net_config(name, "circ")
    pc, sc, _ = train_model(ds, cfg_c, epochs=epochs, log=log)
    sc = recalibrate_bn(pc, sc, cfg_c, ds)
    acc_c, conf_c = evaluate(pc, sc, cfg_c, ds)
    res["acc_circ_digital"] = acc_c

    # -- 3. circulant deployed on chip w/o hardware-aware training --------
    # BN is recalibrated on-chip (the paper's one-shot calibration), which
    # is standard deployment practice; the residual drop is what DPE fixes.
    log(f"  [{name}] config 3/4: circulant -> chip, no DPE")
    dpe_true = true_dpe_from_chip(chp)
    scv = recalibrate_bn(pc, sc, cfg_c, ds, mode="device", dpe=dpe_true)
    acc_v, conf_v = evaluate(pc, scv, cfg_c, ds, mode="device", dpe=dpe_true)
    res["acc_chip_vanilla"] = acc_v

    # -- 4. hardware-aware training with DPE -------------------------------
    log(f"  [{name}] config 4/4: circulant + DPE hardware-aware training")
    key, kcal = jax.random.split(key)
    dpe_hat = fitted_dpe_from_chip(chp, kcal)
    pd, sd, _ = train_model(ds, cfg_c, mode="device", dpe=dpe_hat,
                            epochs=epochs, log=log)
    sd = recalibrate_bn(pd, sd, cfg_c, ds, mode="device", dpe=dpe_true)
    acc_d, conf_d = evaluate(pd, sd, cfg_c, ds, mode="device", dpe=dpe_true)
    res["acc_chip_dpe"] = acc_d

    counts = model.count_params(cfg_c)
    res["params"] = counts
    res["gamma_fit_err"] = float(
        jnp.abs(dpe_hat.gamma_hat - chp.gamma_true).max())
    res["confusion_chip_dpe"] = conf_d.tolist()
    if name == "synth_cxr":
        sn, sp = sens_spec(conf_d, 1)      # class 1 = "covid"
        res["sensitivity_covid"] = float(sn)
        res["specificity_covid"] = float(sp)
    res["wall_s"] = time.time() - t0

    # -- exports for the rust side -----------------------------------------
    mdir = out / "models"
    export.write_bundle(mdir / f"{name}_dpe.cpt",
                        export.model_tensors(pd, sd))
    export.write_bundle(mdir / f"{name}_gemm.cpt",
                        export.model_tensors(pg, sg))
    export.write_manifest(mdir / f"{name}.json", cfg_c,
                          {"dataset": name, "classes": ds["classes"],
                           "acc": res})
    # small test-set slice for the rust serving example
    export.write_bundle(mdir / f"{name}_testset.cpt", {
        "x": ds["test_x"][:128].astype(np.float32),
        "y": ds["test_y"][:128].astype(np.int32),
    })
    return res


def export_chip_and_goldens(out: Path) -> None:
    """Chip description + deterministic golden vectors for rust tests."""
    chp = chip_mod.make_chip(chip_mod.ChipParams())
    (out / "chip.json").write_text(json.dumps(chp.export_dict(), indent=1))
    rng = np.random.default_rng(11)
    goldens = {}
    for i, (p, q, l, b) in enumerate([(3, 5, 4, 8), (12, 12, 4, 4),
                                      (1, 3, 4, 1), (6, 2, 8, 16)]):
        w = rng.uniform(0, 1, (p, q, l)).astype(np.float32)
        x = rng.uniform(0, 1, (q * l, b)).astype(np.float32)
        if l == chp.p.l:
            y = np.asarray(chp.forward(jnp.asarray(w), jnp.asarray(x)))
        else:
            from .kernels import ref
            y = np.asarray(ref.crossbar_forward_ref(
                jnp.asarray(w), jnp.asarray(x), eps=0.0,
                w_bits=6, x_bits=4, dark=0.0))
        goldens[f"case{i}.w"] = w
        goldens[f"case{i}.x"] = x
        goldens[f"case{i}.y"] = y.astype(np.float32)
    export.write_bundle(out / "goldens.cpt", goldens)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="small data / few epochs (CI smoke)")
    ap.add_argument("--datasets", nargs="*",
                    default=list(data_mod.DATASETS))
    args = ap.parse_args()
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    export_chip_and_goldens(out)
    all_res = {}
    for name in args.datasets:
        print(f"== {name} ==")
        all_res[name] = run_dataset(name, out, args.quick)
        r = all_res[name]
        print(f"  gemm {r['acc_gemm_digital']:.4f}  "
              f"circ {r['acc_circ_digital']:.4f}  "
              f"chip-no-dpe {r['acc_chip_vanilla']:.4f}  "
              f"chip+dpe {r['acc_chip_dpe']:.4f}  "
              f"(param reduction {r['params']['reduction_pct']:.2f}%)")
    (out / "metrics.json").write_text(json.dumps(all_res, indent=1))
    print(f"wrote {out / 'metrics.json'}")


if __name__ == "__main__":
    main()
