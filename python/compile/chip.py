"""Differentiable photonic-chip model (jnp mirror of ``rust/src/photonic``).

The paper characterises the fabricated CirPTC by fitting physical device
models to measurements (Fig. 2 d-f) and then drives the DPE from those fits.
We have no chip, so this module *is* the chip (DESIGN.md §2): a
``PhotonicChip`` instance holds hidden, seeded nonideality parameters
(spectral crosstalk, per-wavelength PD responsivity tilt, dark current,
noise magnitudes, fabrication variance of the MRR transmission peaks) and
exposes the same interfaces the real testbed would:

* ``forward(w, x, key)``   — "run the chip": quantized, crosstalk-mixed,
  noisy BCM matmul (lookup-mode ground truth; mirrored bit-for-bit by the
  deterministic part of the rust simulator).
* ``sweep_lut(key)``       — calibration sweep producing (x, y) pairs, the
  stand-in for the paper's measured lookup table.
* ``fit_gamma(lut)``       — least-squares fit of the effective mixing
  operator Γ from the LUT (paper Methods Eq. 5), used by the DPE.

Everything is pure-functional over a frozen parameter dataclass so it can
be jitted and vmapped inside training loops.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref


@dataclasses.dataclass(frozen=True)
class ChipParams:
    """Hidden ("as-fabricated") parameters of one CirPTC instance."""
    l: int = 4                 # circulant block order (paper: order-4)
    eps: float = 0.02          # adjacent-channel spectral crosstalk
    dark: float = 0.015        # PD dark current, normalised output units
    sigma_rel: float = 0.01    # relative (signal-proportional) noise
    sigma_abs: float = 0.003   # absolute (thermal/shot floor) noise
    resp_tilt: float = 0.03    # per-wavelength PD responsivity tilt (peak-peak)
    fab_sigma: float = 0.01    # MRR peak-transmission fabrication variance
    w_bits: int = 6            # weight DAC resolution (paper: 6-bit)
    x_bits: int = 4            # input DAC resolution (paper: 4-bit)
    seed: int = 7


def make_chip(params: ChipParams) -> "PhotonicChip":
    return PhotonicChip(params)


class PhotonicChip:
    """One fabricated CirPTC instance (see module docstring)."""

    def __init__(self, params: ChipParams):
        self.p = params
        l = params.l
        rng = np.random.default_rng(params.seed)
        # true crosstalk operator: nominal Lorentzian-leakage mixing plus a
        # random asymmetric perturbation from fabrication variance
        gamma = np.asarray(ref.crosstalk_matrix(l, params.eps))
        pert = rng.normal(0.0, params.fab_sigma / 2, (l, l))
        pert -= np.diag(np.diag(pert))
        self.gamma_true = jnp.asarray(gamma + pert, dtype=jnp.float32)
        # per-wavelength responsivity tilt (PD + MRR peak variance), the
        # wavelength-dependent response the paper flags for spectral folding
        tilt = np.linspace(-params.resp_tilt / 2, params.resp_tilt / 2, l)
        tilt = tilt + rng.normal(0.0, params.fab_sigma, l)
        self.resp = jnp.asarray(1.0 + tilt, dtype=jnp.float32)

    # -- device-domain transfer -------------------------------------------

    def encode_weights(self, w: jnp.ndarray) -> jnp.ndarray:
        """Quantize + apply per-wavelength responsivity to (P, Q, l) weights.

        Element ``w[p, q, s]`` rides wavelength ``s`` of its block, so the
        responsivity tilt multiplies along the last axis.
        """
        wq = ref.quantize_ref(w, self.p.w_bits) if self.p.w_bits else w
        return wq * self.resp[None, None, :]

    def encode_inputs(self, x: jnp.ndarray) -> jnp.ndarray:
        """Quantize inputs and mix WDM channels with the true Γ."""
        xq = ref.quantize_ref(x, self.p.x_bits) if self.p.x_bits else x
        q = x.shape[0] // self.p.l
        xb = xq.reshape(q, self.p.l, -1)
        xb = jnp.einsum("ij,qjb->qib", self.gamma_true, xb)
        return xb.reshape(x.shape)

    # -- chip execution ----------------------------------------------------

    def forward(self, w: jnp.ndarray, x: jnp.ndarray,
                key: jax.Array | None = None) -> jnp.ndarray:
        """Run one BCM matmul "on chip" (lookup-mode ground truth).

        w: (P, Q, l) in [0, 1];  x: (N, B) in [0, 1];  returns (M, B).
        """
        y = ref.bcm_matmul_ref(self.encode_weights(w), self.encode_inputs(x))
        y = y + self.p.dark
        if key is not None:
            k1, k2 = jax.random.split(key)
            y = y + (jnp.abs(y) * self.p.sigma_rel
                     * jax.random.normal(k1, y.shape)
                     + self.p.sigma_abs * jax.random.normal(k2, y.shape))
        return y

    # -- calibration -------------------------------------------------------

    def sweep_lut(self, key: jax.Array, n_sweep: int = 256,
                  q_blocks: int = 4) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Calibration sweep (the paper's LUT measurement).

        Programs ``n_sweep`` random (w, x) pairs on a (1, q_blocks, l) tile
        and records chip outputs.  Returns (ws, xs, ys).
        """
        l = self.p.l
        kw, kx, kn = jax.random.split(key, 3)
        ws = jax.random.uniform(kw, (n_sweep, 1, q_blocks, l))
        xs = jax.random.uniform(kx, (n_sweep, q_blocks * l, 1))
        def run(w, x, k):
            return self.forward(w, x, k)
        keys = jax.random.split(kn, n_sweep)
        ys = jax.vmap(run)(ws, xs, keys)
        return ws, xs, ys

    def fit_gamma(self, lut: Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Least-squares Γ/gain/offset estimate from a calibration LUT.

        Solves paper Eq. (5): find Γ (l×l), per-wavelength gain ĝ and dark
        offset d̂ minimising ``|y_meas - ĝ∘(W Γ x) - d̂|²`` over the sweep.
        Implementation: because ``y = W Γ x`` is linear in Γ for fixed
        (w, x), stack the sweep into a design matrix and solve with lstsq.
        """
        ws, xs, ys = lut
        n, _, q, l = ws.shape
        # design: y_i = sum_{jk} Γ[j,k] * (W_i e_j)(e_k^T x_i)  + d
        rows = []
        targ = []
        for i in range(n):
            wq = ref.quantize_ref(ws[i], self.p.w_bits)
            xq = ref.quantize_ref(xs[i], self.p.x_bits)
            wd = ref.expand_bcm(wq)                       # (l, q*l)
            xb = np.asarray(xq).reshape(q, l)
            # A[r, (j,k)] = sum_q wd[r, q*l + j] * xb[q, k]
            wblk = np.asarray(wd).reshape(l, q, l)
            a = np.einsum("rqj,qk->rjk", wblk, xb).reshape(l, l * l)
            rows.append(np.concatenate([a, np.eye(l)], axis=1))
            targ.append(np.asarray(ys[i]).reshape(l))
        a = np.concatenate(rows, axis=0)
        b = np.concatenate(targ, axis=0)
        sol, *_ = np.linalg.lstsq(a, b, rcond=None)
        gamma_hat = jnp.asarray(sol[: l * l].reshape(l, l), dtype=jnp.float32)
        dark_hat = jnp.asarray(sol[l * l:], dtype=jnp.float32)
        return gamma_hat, dark_hat, jnp.asarray(self.resp)

    def export_dict(self) -> dict:
        """Serializable chip description (consumed by the rust simulator)."""
        return {
            "l": self.p.l,
            "eps": self.p.eps,
            "dark": self.p.dark,
            "sigma_rel": self.p.sigma_rel,
            "sigma_abs": self.p.sigma_abs,
            "w_bits": self.p.w_bits,
            "x_bits": self.p.x_bits,
            "seed": self.p.seed,
            "gamma_true": np.asarray(self.gamma_true).tolist(),
            "resp": np.asarray(self.resp).tolist(),
        }
