"""Synthetic datasets standing in for SVHN / CIFAR-10 / COVID-QU-Ex.

The paper's datasets may be unavailable offline, so per DESIGN.md §2 we
generate deterministic synthetic sets with the *same tensor shapes and task
structure*: a 10-class digit-glyph set (SVHN stand-in), a 10-class oriented-
texture set (CIFAR-10 stand-in), and a 3-class chest-X-ray-like set
(COVID-QU-Ex stand-in: normal / diffuse / focal).  What we reproduce from
Fig. 4 is the *ordering* of configurations (fp32 GEMM ≥ digital circulant ≥
CirPTC+DPE ≫ CirPTC w/o DPE), which depends on the method, not the corpus.

All generators are pure functions of a seed; images are float32 in [0, 1],
layout NCHW.  The same generators are re-implemented in rust
(rust/src/data/) with identical constants and verified against golden files
exported by ``make artifacts``.
"""

from __future__ import annotations

import numpy as np

# 5x7 bitmap glyphs for the digit dataset (classic calculator font).
_DIGIT_GLYPHS = {
    0: ["11111", "10001", "10001", "10001", "10001", "10001", "11111"],
    1: ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    2: ["11111", "00001", "00001", "11111", "10000", "10000", "11111"],
    3: ["11111", "00001", "00001", "01111", "00001", "00001", "11111"],
    4: ["10001", "10001", "10001", "11111", "00001", "00001", "00001"],
    5: ["11111", "10000", "10000", "11111", "00001", "00001", "11111"],
    6: ["11111", "10000", "10000", "11111", "10001", "10001", "11111"],
    7: ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    8: ["11111", "10001", "10001", "11111", "10001", "10001", "11111"],
    9: ["11111", "10001", "10001", "11111", "00001", "00001", "11111"],
}


def synth_digits(n_train: int = 2048, n_test: int = 512, seed: int = 1,
                 size: int = 32) -> dict:
    """SVHN stand-in: colored digit glyphs on textured backgrounds."""
    rng = np.random.default_rng(seed)
    glyphs = np.zeros((10, 7, 5), np.float32)
    for d, rows in _DIGIT_GLYPHS.items():
        glyphs[d] = np.array([[int(ch) for ch in row] for row in rows])

    def make(n):
        y = rng.integers(0, 10, n)
        x = rng.uniform(0.0, 0.35, (n, 3, size, size)).astype(np.float32)
        for i in range(n):
            scale = rng.integers(2, 4)                  # glyph magnification
            g = np.kron(glyphs[y[i]], np.ones((scale, scale), np.float32))
            gh, gw = g.shape
            r0 = rng.integers(0, size - gh + 1)
            c0 = rng.integers(0, size - gw + 1)
            color = rng.uniform(0.6, 1.0, 3).astype(np.float32)
            for c in range(3):
                patch = x[i, c, r0:r0 + gh, c0:c0 + gw]
                x[i, c, r0:r0 + gh, c0:c0 + gw] = np.where(
                    g > 0, color[c], patch)
        x += rng.normal(0.0, 0.05, x.shape).astype(np.float32)
        return np.clip(x, 0.0, 1.0), y.astype(np.int32)

    xtr, ytr = make(n_train)
    xte, yte = make(n_test)
    return {"train_x": xtr, "train_y": ytr, "test_x": xte, "test_y": yte,
            "classes": 10, "name": "synth_digits"}


def synth_textures(n_train: int = 2048, n_test: int = 512, seed: int = 2,
                   size: int = 32) -> dict:
    """CIFAR-10 stand-in: 10 oriented/frequency Gabor-texture classes."""
    rng = np.random.default_rng(seed)
    thetas = np.pi * np.arange(5) / 5.0                 # 5 orientations
    freqs = np.array([2.0, 4.0])                        # 2 spatial freqs
    yy, xx = np.mgrid[0:size, 0:size] / size

    def make(n):
        y = rng.integers(0, 10, n)
        x = np.zeros((n, 3, size, size), np.float32)
        for i in range(n):
            th = thetas[y[i] % 5] + rng.normal(0, 0.08)
            f = freqs[y[i] // 5] * rng.uniform(0.9, 1.1)
            phase = rng.uniform(0, 2 * np.pi)
            u = np.cos(th) * xx + np.sin(th) * yy
            base = 0.5 + 0.45 * np.sin(2 * np.pi * f * u + phase)
            tint = rng.uniform(0.7, 1.0, 3)
            for c in range(3):
                x[i, c] = base * tint[c]
        x += rng.normal(0.0, 0.08, x.shape).astype(np.float32)
        return np.clip(x, 0.0, 1.0).astype(np.float32), y.astype(np.int32)

    xtr, ytr = make(n_train)
    xte, yte = make(n_test)
    return {"train_x": xtr, "train_y": ytr, "test_x": xte, "test_y": yte,
            "classes": 10, "name": "synth_textures"}


def synth_cxr(n_train: int = 1536, n_test: int = 384, seed: int = 3,
              size: int = 64) -> dict:
    """COVID-QU-Ex stand-in: 3-class grayscale chest-X-ray-like images.

    class 0 "normal"  — clear lung fields;
    class 1 "covid"   — diffuse bilateral ground-glass haze;
    class 2 "pneumonia" — focal unilateral opacities.
    """
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:size, 0:size] / size

    def lung_fields():
        # two elliptic bright regions on a dark thorax
        img = 0.15 + 0.1 * yy
        for cx in (0.32, 0.68):
            d = ((xx - cx) / 0.18) ** 2 + ((yy - 0.52) / 0.32) ** 2
            img = img + 0.55 * np.exp(-d * 1.5)
        return img

    def make(n):
        y = rng.integers(0, 3, n)
        x = np.zeros((n, 1, size, size), np.float32)
        for i in range(n):
            img = lung_fields() * rng.uniform(0.9, 1.1)
            if y[i] == 1:                                # diffuse haze
                haze = rng.uniform(0.12, 0.25)
                u = np.cos(rng.uniform(0, np.pi)) * xx + \
                    np.sin(rng.uniform(0, np.pi)) * yy
                img += haze * (0.6 + 0.4 * np.sin(2 * np.pi * 3 * u))
            elif y[i] == 2:                              # focal opacities
                for _ in range(rng.integers(1, 4)):
                    cx = rng.uniform(0.2, 0.8)
                    cy = rng.uniform(0.3, 0.75)
                    rad = rng.uniform(0.05, 0.12)
                    d = ((xx - cx) ** 2 + (yy - cy) ** 2) / rad ** 2
                    img += 0.35 * np.exp(-d)
            img += rng.normal(0.0, 0.04, img.shape)
            x[i, 0] = np.clip(img, 0.0, 1.0)
        return x, y.astype(np.int32)

    xtr, ytr = make(n_train)
    xte, yte = make(n_test)
    return {"train_x": xtr, "train_y": ytr, "test_x": xte, "test_y": yte,
            "classes": 3, "name": "synth_cxr"}


DATASETS = {
    "synth_digits": synth_digits,
    "synth_textures": synth_textures,
    "synth_cxr": synth_cxr,
}
